//! ChaNGa-style N-Body simulation on the G-Charm runtime (paper section 4.1).
//!
//! Per iteration: (domain decomposition +) tree construction, per-bucket
//! tree walks producing interaction lists, gravitational force work
//! requests, Ewald periodic corrections, integration. The walk/submit/
//! accumulate cycle runs message-driven across TreePiece chares; force and
//! Ewald kernels execute on the (simulated) GPU through the runtime's
//! combining/reuse/coalescing strategies.
//!
//! Three drivers back the Fig 2/3/4 experiments:
//!   - [`run`]            : the G-Charm path (configurable strategies)
//!   - [`run_cpu_only`]   : multi-core CPU baseline (forces inline on PEs)
//!   - [`handtuned::run_handtuned`] : Jetley-et-al-style hand-tuned GPU
//!     driver that bypasses the runtime entirely.

pub mod dataset;
pub mod ewald;
pub mod handtuned;
pub mod tree;
pub mod treepiece;
pub mod walk;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::{
    ewald_descriptor, force_descriptor, ChareId, Config, JobSpec, Msg,
    Report, Runtime,
};

use dataset::DatasetSpec;
use tree::{Particle, Tree};
use treepiece::{StartMsg, TreePiece, METHOD_START};

/// Chare collection id of TreePieces.
pub const NBODY_COLLECTION: u32 = 1;

/// N-Body experiment configuration.
#[derive(Debug, Clone)]
pub struct NbodyConfig {
    pub dataset: DatasetSpec,
    /// Chares per PE (over-decomposition factor; Charm++ style).
    pub pieces_per_pe: usize,
    pub iters: usize,
    /// Barnes-Hut opening angle.
    pub theta: f64,
    pub dt: f64,
    pub do_ewald: bool,
    /// Ewald splitting parameter (1/box units scale).
    pub alpha: f64,
    pub eps2: f32,
    /// Runtime configuration (PEs, combining, data policy, ...).
    pub runtime: Config,
}

impl NbodyConfig {
    pub fn new(dataset: DatasetSpec) -> NbodyConfig {
        let iters = dataset.iters.min(8);
        NbodyConfig {
            dataset,
            pieces_per_pe: 4,
            iters,
            theta: 0.7,
            dt: 1e-3,
            do_ewald: true,
            alpha: 2.0,
            eps2: 1e-2,
            runtime: Config::default(),
        }
    }

    /// The Ewald k-vector table for this configuration.
    pub fn ktable(&self) -> Vec<f32> {
        ewald::ktable(
            self.dataset.box_size,
            self.alpha / self.dataset.box_size,
        )
    }
}

/// Outcome of an N-Body run.
#[derive(Debug)]
pub struct NbodyResult {
    pub report: Report,
    /// End-to-end wall seconds (all iterations, including tree builds).
    pub wall: f64,
    /// Total energy (kinetic + potential/2) per iteration.
    pub energies: Vec<f64>,
    /// Buckets in the final tree.
    pub buckets: usize,
}

/// Assign buckets to pieces in contiguous Morton blocks (spatial locality,
/// like ChaNGa's space-filling-curve decomposition).
fn assign_buckets(nbuckets: usize, pieces: usize) -> Vec<Vec<usize>> {
    let mut out = vec![Vec::new(); pieces];
    let per = nbuckets.div_ceil(pieces.max(1));
    for b in 0..nbuckets {
        out[(b / per).min(pieces - 1)].push(b);
    }
    out
}

/// Build the N-Body workload as a [`JobSpec`] for a (possibly shared)
/// [`Runtime`]: the TreePiece chare set, the gravity + Ewald family
/// registrations, and a driver pacing `cfg.iters` iterations (tree
/// build, walks, force/Ewald requests, integration, per-job buffer
/// invalidation). The driver's series is the total energy per iteration.
pub fn job_spec(cfg: &NbodyConfig) -> JobSpec {
    job_spec_inner(cfg, "nbody", false).0
}

/// [`job_spec`] variants used by the drivers below: `cpu_only` keeps the
/// chare structure but computes forces inline on the PEs; the returned
/// counter reports the final tree's bucket count after the job ran.
fn job_spec_inner(
    cfg: &NbodyConfig,
    name: &str,
    cpu_only: bool,
) -> (JobSpec, Arc<AtomicUsize>) {
    let particles = cfg.dataset.generate();
    let master = Arc::new(Mutex::new(particles));
    let ktab = Arc::new(cfg.ktable());
    let npieces = (cfg.runtime.pes * cfg.pieces_per_pe).max(1);

    let mut spec = JobSpec::new(name)
        // Register the app's kernel families: this is the whole GPU
        // surface the app needs — the runtime learns the shapes,
        // occupancy, and reuse wiring from the descriptors.
        .kernel(force_descriptor(cfg.eps2))
        .kernel(ewald_descriptor(ktab.to_vec()));
    for i in 0..npieces {
        let id = ChareId::new(NBODY_COLLECTION, i as u32);
        spec = spec.chare(id, i, Box::new(TreePiece::new(id)));
    }

    let buckets_out = Arc::new(AtomicUsize::new(0));
    let buckets_probe = buckets_out.clone();
    let iters = cfg.iters;
    let theta = cfg.theta;
    let dt = cfg.dt;
    let do_ewald = cfg.do_ewald;
    let eps2 = cfg.eps2;
    let spec = spec.driver(move |ctx| {
        let force_kind = ctx.kinds()[0];
        let ewald_kind = ctx.kinds()[1];
        let mut energies = Vec::with_capacity(iters);
        for _ in 0..iters {
            let snapshot: Arc<Vec<Particle>> =
                Arc::new(master.lock().unwrap().clone());
            let tree = Tree::build(&snapshot);
            buckets_probe.store(tree.buckets.len(), Ordering::SeqCst);
            let assignment = assign_buckets(tree.buckets.len(), npieces);
            for (i, bucket_ids) in assignment.into_iter().enumerate() {
                ctx.send(
                    ChareId::new(NBODY_COLLECTION, i as u32),
                    Msg::new(
                        METHOD_START,
                        StartMsg {
                            tree: tree.clone(),
                            snapshot: snapshot.clone(),
                            master: master.clone(),
                            buckets: bucket_ids,
                            force_kind,
                            ewald_kind,
                            theta,
                            dt,
                            do_ewald,
                            cpu_only,
                            eps2,
                            ktab: ktab.clone(),
                        },
                    ),
                );
            }
            energies.push(ctx.await_reduction(npieces as u64)?);
            ctx.await_quiescence();
            // positions changed: this job's resident buffers are stale
            ctx.invalidate_buffers();
        }
        Ok(energies)
    });
    (spec, buckets_out)
}

fn run_inner(cfg: &NbodyConfig, cpu_only: bool) -> Result<NbodyResult> {
    let (spec, buckets) = job_spec_inner(cfg, "nbody", cpu_only);
    let rt = Runtime::new(cfg.runtime.clone())?;
    let t0 = Instant::now();
    let handle = rt.submit_job(spec)?;
    let job = handle.wait()?;
    let wall = t0.elapsed().as_secs_f64();
    let mut report = rt.shutdown();
    report.total_wall = wall;
    Ok(NbodyResult {
        report,
        wall,
        energies: job.series,
        buckets: buckets.load(Ordering::SeqCst),
    })
}

/// Run on the G-Charm runtime (GPU path with the configured strategies).
pub fn run(cfg: &NbodyConfig) -> Result<NbodyResult> {
    run_inner(cfg, false)
}

/// Multi-core CPU baseline: same chare structure, forces computed inline
/// on the PEs (no work requests, no GPU). The Fig 4 "CPU" series.
pub fn run_cpu_only(cfg: &NbodyConfig) -> Result<NbodyResult> {
    run_inner(cfg, true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_assignment_partitions() {
        let a = assign_buckets(10, 3);
        assert_eq!(a.len(), 3);
        let all: Vec<usize> = a.iter().flatten().copied().collect();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn bucket_assignment_more_pieces_than_buckets() {
        let a = assign_buckets(2, 5);
        let total: usize = a.iter().map(|v| v.len()).sum();
        assert_eq!(total, 2);
    }
}
