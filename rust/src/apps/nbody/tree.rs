//! Barnes-Hut octree over the particle set.
//!
//! ChaNGa divides particles among TreePiece chares, each holding part of
//! the global tree; particles are grouped into *buckets* and all particles
//! in a bucket interact with the same nodes/particles (paper section 4.1).
//! Here the tree is built once per iteration from the master particle
//! array (Morton-sorted, recursive spatial split) and shared read-only
//! with every TreePiece; buckets are the leaves, capped at
//! `PARTS_PER_BUCKET` particles so one bucket = one work request = one
//! "CUDA block" (section 4.3).

use std::sync::Arc;

use crate::runtime::shapes::PARTS_PER_BUCKET;
use crate::util::{morton, Vec3};

/// One body. Host physics state is f64; kernels see f32 projections.
#[derive(Debug, Clone, Copy)]
pub struct Particle {
    pub pos: Vec3,
    pub vel: Vec3,
    pub mass: f64,
    pub acc: Vec3,
    pub pot: f64,
}

impl Particle {
    pub fn at_rest(pos: Vec3, mass: f64) -> Particle {
        Particle { pos, vel: Vec3::ZERO, mass, acc: Vec3::ZERO, pot: 0.0 }
    }
}

/// Tree node: a cubic cell.
#[derive(Debug, Clone)]
pub struct Node {
    pub center: Vec3,
    /// Half side length of the cell.
    pub half: f64,
    /// Center of mass and total mass of the subtree.
    pub com: Vec3,
    pub mass: f64,
    /// Child node indices (-1 = absent).
    pub children: [i32; 8],
    /// Bucket index if this is a leaf, else -1.
    pub bucket: i32,
    /// Particles in the subtree.
    pub count: usize,
    /// Range into `Tree::order`.
    pub start: usize,
    pub end: usize,
}

/// Leaf bucket: a contiguous Morton-order range of particles.
#[derive(Debug, Clone, Copy)]
pub struct Bucket {
    pub start: usize,
    pub end: usize,
    pub node: usize,
}

impl Bucket {
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// The global Barnes-Hut tree for one iteration.
#[derive(Debug)]
pub struct Tree {
    pub nodes: Vec<Node>,
    /// Particle indices in Morton order.
    pub order: Vec<u32>,
    pub buckets: Vec<Bucket>,
    pub lo: Vec3,
    pub hi: Vec3,
}

const MAX_DEPTH: usize = 24;

impl Tree {
    /// Build from the particle array. O(n log n).
    pub fn build(parts: &[Particle]) -> Arc<Tree> {
        assert!(!parts.is_empty());
        let mut lo = parts[0].pos;
        let mut hi = parts[0].pos;
        for p in parts {
            lo = lo.min(p.pos);
            hi = hi.max(p.pos);
        }
        // pad so nothing sits exactly on the boundary
        let span = (hi - lo).max_component().max(1e-9);
        let pad = span * 1e-6;
        lo = lo - Vec3::new(pad, pad, pad);
        hi = hi + Vec3::new(pad, pad, pad);
        let side = (hi - lo).max_component();
        let lof = lo;

        let mut keyed: Vec<(u64, u32)> = parts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let rel = p.pos - lof;
                (
                    morton::from_position(
                        [rel.x, rel.y, rel.z],
                        0.0,
                        side.max(1e-12),
                    ),
                    i as u32,
                )
            })
            .collect();
        keyed.sort_unstable_by_key(|&(k, _)| k);
        let order: Vec<u32> = keyed.iter().map(|&(_, i)| i).collect();

        let mut tree = Tree {
            nodes: Vec::with_capacity(parts.len() / 4),
            order,
            buckets: Vec::new(),
            lo,
            hi,
        };
        let center = lo + Vec3::new(side / 2.0, side / 2.0, side / 2.0);
        tree.build_node(parts, 0, parts.len(), center, side / 2.0, 0);
        Arc::new(tree)
    }

    /// Recursively build the node covering order[start..end]; returns index.
    fn build_node(
        &mut self,
        parts: &[Particle],
        start: usize,
        end: usize,
        center: Vec3,
        half: f64,
        depth: usize,
    ) -> i32 {
        if start == end {
            return -1;
        }
        let idx = self.nodes.len();
        self.nodes.push(Node {
            center,
            half,
            com: Vec3::ZERO,
            mass: 0.0,
            children: [-1; 8],
            bucket: -1,
            count: end - start,
            start,
            end,
        });

        if end - start <= PARTS_PER_BUCKET || depth >= MAX_DEPTH {
            let b = self.buckets.len();
            self.buckets.push(Bucket { start, end, node: idx });
            self.nodes[idx].bucket = b as i32;
        } else {
            // Partition the range into octants around the center. The range
            // is Morton-sorted, so each octant is a contiguous subrange; a
            // simple stable partition by octant id keeps it correct even
            // with duplicate positions.
            let mut groups: [Vec<u32>; 8] = Default::default();
            for &pi in &self.order[start..end] {
                let p = parts[pi as usize].pos;
                let o = ((p.x >= center.x) as usize)
                    | (((p.y >= center.y) as usize) << 1)
                    | (((p.z >= center.z) as usize) << 2);
                groups[o].push(pi);
            }
            let mut cursor = start;
            let q = half / 2.0;
            for (o, group) in groups.iter().enumerate() {
                if group.is_empty() {
                    continue;
                }
                let cstart = cursor;
                for (j, &pi) in group.iter().enumerate() {
                    self.order[cstart + j] = pi;
                }
                cursor += group.len();
                let ccenter = center
                    + Vec3::new(
                        if o & 1 != 0 { q } else { -q },
                        if o & 2 != 0 { q } else { -q },
                        if o & 4 != 0 { q } else { -q },
                    );
                let child = self.build_node(
                    parts, cstart, cursor, ccenter, q, depth + 1,
                );
                self.nodes[idx].children[o] = child;
            }
        }

        // center of mass bottom-up
        let (mut m, mut com) = (0.0f64, Vec3::ZERO);
        for &pi in &self.order[start..end] {
            let p = &parts[pi as usize];
            m += p.mass;
            com += p.pos * p.mass;
        }
        self.nodes[idx].mass = m;
        self.nodes[idx].com = if m > 0.0 { com / m } else { center };
        idx as i32
    }

    pub fn root(&self) -> &Node {
        &self.nodes[0]
    }

    /// Particle indices of a bucket.
    pub fn bucket_particles(&self, b: usize) -> &[u32] {
        let bk = &self.buckets[b];
        &self.order[bk.start..bk.end]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::nbody::dataset::DatasetSpec;

    fn parts() -> Vec<Particle> {
        DatasetSpec::tiny().generate()
    }

    #[test]
    fn buckets_partition_particles() {
        let ps = parts();
        let tree = Tree::build(&ps);
        let mut seen = vec![false; ps.len()];
        for b in 0..tree.buckets.len() {
            for &pi in tree.bucket_particles(b) {
                assert!(!seen[pi as usize], "particle in two buckets");
                seen[pi as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "particle missing from buckets");
    }

    #[test]
    fn bucket_sizes_capped() {
        let tree = Tree::build(&parts());
        for b in &tree.buckets {
            assert!(b.len() <= PARTS_PER_BUCKET);
            assert!(!b.is_empty());
        }
    }

    #[test]
    fn root_mass_is_total() {
        let ps = parts();
        let tree = Tree::build(&ps);
        let total: f64 = ps.iter().map(|p| p.mass).sum();
        assert!((tree.root().mass - total).abs() < 1e-9);
        assert_eq!(tree.root().count, ps.len());
    }

    #[test]
    fn node_ranges_nest() {
        let ps = parts();
        let tree = Tree::build(&ps);
        for n in &tree.nodes {
            let mut child_count = 0usize;
            for &c in &n.children {
                if c >= 0 {
                    let ch = &tree.nodes[c as usize];
                    assert!(ch.start >= n.start && ch.end <= n.end);
                    child_count += ch.count;
                }
            }
            if n.bucket < 0 {
                assert_eq!(child_count, n.count, "internal node loses bodies");
            }
        }
    }

    #[test]
    fn particles_inside_their_cells() {
        let ps = parts();
        let tree = Tree::build(&ps);
        for n in &tree.nodes {
            // COM must lie within the cell (sanity of the split)
            let d = n.com - n.center;
            let eps = n.half * 1.01 + 1e-9;
            assert!(
                d.x.abs() <= eps && d.y.abs() <= eps && d.z.abs() <= eps,
                "com escapes cell"
            );
        }
    }

    #[test]
    fn single_particle_tree() {
        let ps = vec![Particle::at_rest(Vec3::new(1.0, 2.0, 3.0), 5.0)];
        let tree = Tree::build(&ps);
        assert_eq!(tree.buckets.len(), 1);
        assert_eq!(tree.root().mass, 5.0);
    }

    #[test]
    fn coincident_particles_terminate() {
        // identical positions would recurse forever without the depth cap
        let ps = vec![Particle::at_rest(Vec3::new(1.0, 1.0, 1.0), 1.0); 40];
        let tree = Tree::build(&ps);
        let total: usize = tree.buckets.iter().map(|b| b.len()).sum();
        assert_eq!(total, 40);
    }
}
