//! Hand-tuned hybrid N-Body driver (the Fig 4 comparison target).
//!
//! Models the Jetley-et-al ChaNGa GPU code the paper compares against
//! (section 4.5): developers manually tuned data layout, batching, and
//! transfers. Correspondingly this driver bypasses the G-Charm runtime
//! completely -- no chares, no combiner, no chare table:
//!
//!   - walks run data-parallel across worker threads (perfect knowledge of
//!     the whole iteration's work),
//!   - force chunks are packed into contiguous, fully-coalesced launches of
//!     exactly maxSize (104) buckets, Ewald of 65 -- optimal occupancy with
//!     zero idle waiting,
//!   - outputs are folded straight into the particle array.
//!
//! The paper's finding: G-Charm approaches but does not beat this (runtime
//! overheads, generic strategies); our Fig 4 bench checks the same ordering.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::Report;
use crate::runtime::device_sim::CoalescingClass;
use crate::runtime::executor::{Executor, LaunchSpec, Payload};
use crate::runtime::workqueue::LaunchMode;
use crate::runtime::kernel::TileKernel;
use crate::runtime::shapes::{
    INTERACTIONS, INTER_W, OUT_W, PARTICLE_W, PARTS_PER_BUCKET,
};
use crate::util::Vec3;

use super::tree::Tree;
use super::walk::interaction_list;
use super::{NbodyConfig, NbodyResult};

/// One packed bucket chunk ready for launching.
struct Unit {
    bucket: usize,
    parts: Vec<f32>,
    inters: Vec<f32>,
}

/// Run the hand-tuned driver.
pub fn run_handtuned(cfg: &NbodyConfig) -> Result<NbodyResult> {
    let mut particles = cfg.dataset.generate();
    let gravity = Arc::new(TileKernel::gravity(cfg.eps2));
    let ewald = Arc::new(TileKernel::ewald(cfg.ktable()));
    let mut exec = Executor::new(
        &cfg.runtime.artifacts,
        vec![gravity.clone(), ewald.clone()],
    )?;
    let force_max = gravity.max_combine();
    let ewald_max = ewald.max_combine();

    let t0 = Instant::now();
    let mut energies = Vec::with_capacity(cfg.iters);
    let mut report = Report::default();
    let mut buckets = 0usize;
    let mut launch_id = 0u64;

    for _ in 0..cfg.iters {
        let snapshot = Arc::new(particles.clone());
        let tree = Tree::build(&snapshot);
        buckets = tree.buckets.len();

        // Parallel walks: static block partition across worker threads
        // (the hand-tuner knows the whole iteration in advance).
        let nthreads = cfg.runtime.pes.max(1);
        let units: Vec<Unit> = std::thread::scope(|scope| {
            let tree = &tree;
            let snapshot = &snapshot;
            let mut handles = Vec::new();
            let per = buckets.div_ceil(nthreads);
            for t in 0..nthreads {
                let lo = (t * per).min(buckets);
                let hi = ((t + 1) * per).min(buckets);
                handles.push(scope.spawn(move || {
                    let mut out = Vec::new();
                    for b in lo..hi {
                        let pids = tree.bucket_particles(b);
                        let mut pbuf =
                            vec![0.0f32; PARTS_PER_BUCKET * PARTICLE_W];
                        for (j, &pi) in pids.iter().enumerate() {
                            let p = &snapshot[pi as usize];
                            pbuf[j * PARTICLE_W] = p.pos.x as f32;
                            pbuf[j * PARTICLE_W + 1] = p.pos.y as f32;
                            pbuf[j * PARTICLE_W + 2] = p.pos.z as f32;
                            pbuf[j * PARTICLE_W + 3] = p.mass as f32;
                        }
                        let (list, _) =
                            interaction_list(tree, snapshot, b, cfg.theta);
                        for chunk in list.chunks(INTERACTIONS) {
                            let mut inters =
                                vec![0.0f32; INTERACTIONS * INTER_W];
                            for (k, e) in chunk.iter().enumerate() {
                                inters[k * INTER_W..k * INTER_W + 4]
                                    .copy_from_slice(e);
                            }
                            out.push(Unit {
                                bucket: b,
                                parts: pbuf.clone(),
                                inters,
                            });
                        }
                    }
                    out
                }));
            }
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });

        // accumulate per-particle
        let mut acc = vec![(Vec3::ZERO, 0.0f64); particles.len()];

        // force launches of exactly force_max units
        for group in units.chunks(force_max) {
            let n = group.len();
            let mut parts = Vec::with_capacity(n * PARTS_PER_BUCKET * PARTICLE_W);
            let mut inters = Vec::with_capacity(n * INTERACTIONS * INTER_W);
            let mut bytes = 0u64;
            for u in group {
                parts.extend_from_slice(&u.parts);
                inters.extend_from_slice(&u.inters);
                bytes += ((u.parts.len() + u.inters.len()) * 4) as u64;
            }
            let done = exec.run(LaunchSpec {
                id: launch_id,
                payload: Payload::Tile {
                    kernel: gravity.clone(),
                    bufs: vec![parts, inters],
                    batch: n,
                },
                transfer_bytes: bytes,
                pattern: CoalescingClass::Contiguous,
                mode: LaunchMode::PerBatch,
            })?;
            launch_id += 1;
            report.launches += 1;
            report.per_batch_launches += 1;
            report.gpu_requests += n as u64;
            report.kernel_wall += done.wall;
            report.kernel_modeled += done.modeled.kernel;
            report.transfer_modeled += done.modeled.transfer;
            report.transfer_bytes += bytes;
            for (i, u) in group.iter().enumerate() {
                fold(&tree, u.bucket, &done.out[i * PARTS_PER_BUCKET * OUT_W..], &mut acc);
            }
        }

        // Ewald: one unit per bucket, launches of ewald_max
        if cfg.do_ewald {
            let bucket_bufs: Vec<(usize, Vec<f32>)> = (0..buckets)
                .map(|b| {
                    let pids = tree.bucket_particles(b);
                    let mut pbuf = vec![0.0f32; PARTS_PER_BUCKET * PARTICLE_W];
                    for (j, &pi) in pids.iter().enumerate() {
                        let p = &snapshot[pi as usize];
                        pbuf[j * PARTICLE_W] = p.pos.x as f32;
                        pbuf[j * PARTICLE_W + 1] = p.pos.y as f32;
                        pbuf[j * PARTICLE_W + 2] = p.pos.z as f32;
                        pbuf[j * PARTICLE_W + 3] = p.mass as f32;
                    }
                    (b, pbuf)
                })
                .collect();
            for group in bucket_bufs.chunks(ewald_max) {
                let n = group.len();
                let mut parts =
                    Vec::with_capacity(n * PARTS_PER_BUCKET * PARTICLE_W);
                let mut bytes = 0u64;
                for (_, pbuf) in group {
                    parts.extend_from_slice(pbuf);
                    bytes += (pbuf.len() * 4) as u64;
                }
                let done = exec.run(LaunchSpec {
                    id: launch_id,
                    payload: Payload::Tile {
                        kernel: ewald.clone(),
                        bufs: vec![parts],
                        batch: n,
                    },
                    transfer_bytes: bytes,
                    pattern: CoalescingClass::Contiguous,
                    mode: LaunchMode::PerBatch,
                })?;
                launch_id += 1;
                report.launches += 1;
                report.per_batch_launches += 1;
                report.gpu_requests += n as u64;
                report.kernel_wall += done.wall;
                report.kernel_modeled += done.modeled.kernel;
                report.transfer_modeled += done.modeled.transfer;
                report.transfer_bytes += bytes;
                for (i, (b, _)) in group.iter().enumerate() {
                    fold(
                        &tree,
                        *b,
                        &done.out[i * PARTS_PER_BUCKET * OUT_W..],
                        &mut acc,
                    );
                }
            }
        }

        // integrate + energy
        let mut kinetic = 0.0f64;
        let mut potential = 0.0f64;
        for (pi, p) in particles.iter_mut().enumerate() {
            kinetic += 0.5 * p.mass * p.vel.norm2();
            let (a, pot) = acc[pi];
            potential += 0.5 * p.mass * pot;
            p.acc = a;
            p.pot = pot;
            p.vel += a * cfg.dt;
            p.pos += p.vel * cfg.dt;
        }
        energies.push(kinetic + potential);
    }

    let wall = t0.elapsed().as_secs_f64();
    report.total_wall = wall;
    Ok(NbodyResult { report, wall, energies, buckets })
}

fn fold(tree: &Tree, bucket: usize, out: &[f32], acc: &mut [(Vec3, f64)]) {
    for (j, &pi) in tree.bucket_particles(bucket).iter().enumerate() {
        let slot = &mut acc[pi as usize];
        slot.0 += Vec3::new(
            out[j * OUT_W] as f64,
            out[j * OUT_W + 1] as f64,
            out[j * OUT_W + 2] as f64,
        );
        slot.1 += out[j * OUT_W + 3] as f64;
    }
}
