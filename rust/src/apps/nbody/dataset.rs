//! Synthetic cosmology datasets for the ChaNGa-style N-Body app.
//!
//! The paper evaluates on `cube300` (48^3 particles, 300 Mpc box, 128
//! iterations) and `lambs` (144^3 particles, 71 Mpc box, 10 iterations),
//! both "moderately clustered on small scales, uniform at large scales"
//! (section 4.1). Those proprietary snapshot files are not available, so we
//! generate matching *statistical* equivalents: Plummer-profile clusters
//! whose centers are uniform in the box (DESIGN.md section 2 substitution
//! table). The irregularity the strategies exploit -- widely varying
//! interaction-list lengths and task arrival times -- comes from exactly
//! this clustering.

use crate::util::{Rng, Vec3};

use super::tree::Particle;

/// A named dataset recipe.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// Particle count.
    pub n: usize,
    /// Number of Plummer clusters (0 = uniform).
    pub clusters: usize,
    /// Box side length (code units).
    pub box_size: f64,
    /// Plummer scale radius as a fraction of the box.
    pub scale: f64,
    /// Default iteration count in the paper's experiment.
    pub iters: usize,
    pub seed: u64,
}

impl DatasetSpec {
    /// cube300 analog, full scale: 48^3 particles.
    pub fn cube300() -> DatasetSpec {
        DatasetSpec {
            name: "cube300",
            n: 48 * 48 * 48,
            clusters: 64,
            box_size: 300.0,
            scale: 0.02,
            iters: 128,
            seed: 300,
        }
    }

    /// lambs analog, full scale: 144^3 particles.
    pub fn lambs() -> DatasetSpec {
        DatasetSpec {
            name: "lambs",
            n: 144 * 144 * 144,
            clusters: 256,
            box_size: 71.0,
            scale: 0.015,
            iters: 10,
            seed: 71,
        }
    }

    /// Reduced cube300: same clustering statistics, fewer particles --
    /// the "small dataset" rows of Fig 2/4 at bench scale.
    pub fn small() -> DatasetSpec {
        DatasetSpec { n: 16 * 1024, clusters: 24, ..DatasetSpec::cube300() }
    }

    /// Reduced lambs: the "large dataset" rows at bench scale.
    pub fn large() -> DatasetSpec {
        DatasetSpec { n: 48 * 1024, clusters: 64, ..DatasetSpec::lambs() }
    }

    /// Tiny spec for unit/integration tests.
    pub fn tiny() -> DatasetSpec {
        DatasetSpec {
            name: "tiny",
            n: 512,
            clusters: 4,
            box_size: 10.0,
            scale: 0.05,
            iters: 2,
            seed: 7,
        }
    }

    /// Generate the particle set.
    pub fn generate(&self) -> Vec<Particle> {
        let mut rng = Rng::new(self.seed);
        let mut parts = Vec::with_capacity(self.n);
        let mass = 1.0 / self.n as f64;
        if self.clusters == 0 {
            for _ in 0..self.n {
                let pos = Vec3::new(
                    rng.range(0.0, self.box_size),
                    rng.range(0.0, self.box_size),
                    rng.range(0.0, self.box_size),
                );
                parts.push(Particle::at_rest(pos, mass));
            }
            return parts;
        }

        // Cluster centers uniform in the box; populations drawn with a
        // heavy tail so piece workloads differ (irregularity).
        let centers: Vec<Vec3> = (0..self.clusters)
            .map(|_| {
                Vec3::new(
                    rng.range(0.1, 0.9) * self.box_size,
                    rng.range(0.1, 0.9) * self.box_size,
                    rng.range(0.1, 0.9) * self.box_size,
                )
            })
            .collect();
        let mut weights: Vec<f64> =
            (0..self.clusters).map(|_| rng.exponential(1.0) + 0.1).collect();
        let wsum: f64 = weights.iter().sum();
        weights.iter_mut().for_each(|w| *w /= wsum);

        let a = self.scale * self.box_size; // Plummer scale radius
        for c in 0..self.clusters {
            let count = if c + 1 == self.clusters {
                self.n - parts.len()
            } else {
                (weights[c] * self.n as f64).round() as usize
            };
            for _ in 0..count.min(self.n - parts.len()) {
                let pos = centers[c] + plummer_offset(&mut rng, a);
                let pos = Vec3::new(
                    pos.x.clamp(0.0, self.box_size),
                    pos.y.clamp(0.0, self.box_size),
                    pos.z.clamp(0.0, self.box_size),
                );
                parts.push(Particle::at_rest(pos, mass));
            }
        }
        // Top up if rounding lost a few.
        while parts.len() < self.n {
            let pos = Vec3::new(
                rng.range(0.0, self.box_size),
                rng.range(0.0, self.box_size),
                rng.range(0.0, self.box_size),
            );
            parts.push(Particle::at_rest(pos, mass));
        }
        parts
    }
}

/// Sample an isotropic offset with a Plummer radial profile
/// (r = a / sqrt(u^{-2/3} - 1)).
fn plummer_offset(rng: &mut Rng, a: f64) -> Vec3 {
    let u = rng.f64().max(1e-9);
    let r = a / (u.powf(-2.0 / 3.0) - 1.0).max(1e-12).sqrt();
    let r = r.min(20.0 * a); // clip the tail
    // uniform direction
    let z = rng.range(-1.0, 1.0);
    let phi = rng.range(0.0, std::f64::consts::TAU);
    let s = (1.0 - z * z).sqrt();
    Vec3::new(r * s * phi.cos(), r * s * phi.sin(), r * z)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_count() {
        let spec = DatasetSpec::tiny();
        let parts = spec.generate();
        assert_eq!(parts.len(), spec.n);
    }

    #[test]
    fn particles_inside_box() {
        let spec = DatasetSpec::tiny();
        for p in spec.generate() {
            for v in [p.pos.x, p.pos.y, p.pos.z] {
                assert!((0.0..=spec.box_size).contains(&v));
            }
        }
    }

    #[test]
    fn total_mass_normalized() {
        let spec = DatasetSpec::tiny();
        let m: f64 = spec.generate().iter().map(|p| p.mass).sum();
        assert!((m - 1.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = DatasetSpec::tiny().generate();
        let b = DatasetSpec::tiny().generate();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.pos, y.pos);
        }
    }

    #[test]
    fn clustered_is_clumpier_than_uniform() {
        // variance of per-cell counts on a coarse grid is much higher for
        // the clustered dataset
        let clustered = DatasetSpec::tiny().generate();
        let uniform =
            DatasetSpec { clusters: 0, ..DatasetSpec::tiny() }.generate();
        let var = |parts: &[Particle]| {
            let g = 4usize;
            let mut counts = vec![0f64; g * g * g];
            for p in parts {
                let f = |v: f64| {
                    ((v / 10.0 * g as f64) as usize).min(g - 1)
                };
                counts[f(p.pos.x) * g * g + f(p.pos.y) * g + f(p.pos.z)] += 1.0;
            }
            let m = counts.iter().sum::<f64>() / counts.len() as f64;
            counts.iter().map(|c| (c - m) * (c - m)).sum::<f64>()
                / counts.len() as f64
        };
        assert!(var(&clustered) > 4.0 * var(&uniform));
    }

    #[test]
    fn paper_scale_specs() {
        assert_eq!(DatasetSpec::cube300().n, 110_592);
        assert_eq!(DatasetSpec::lambs().n, 2_985_984);
        assert_eq!(DatasetSpec::cube300().iters, 128);
        assert_eq!(DatasetSpec::lambs().iters, 10);
    }
}
