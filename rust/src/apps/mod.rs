//! The paper's two evaluation applications, built on the G-Charm runtime.

pub mod md;
pub mod nbody;
