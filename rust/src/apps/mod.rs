//! Applications built on the G-Charm runtime: the paper's two evaluation
//! workloads (N-Body, MD) plus an SpMV-style sparse neighbor-update
//! mini-app registered purely through the open kernel-registry API.

pub mod md;
pub mod nbody;
pub mod spmv;
