//! Irregular sparse neighbor-update mini-app (SpMV-style Jacobi sweeps).
//!
//! The proof that the kernel surface is open: this workload registers its
//! own kernel family (`spmv_row`) through the public
//! [`crate::coordinator::GCharm::register_kernel`] API and never touches
//! any file under `coordinator/` or `runtime/`. One chare per CSR row;
//! row lengths follow a heavy-tailed distribution, so per-request
//! workloads vary wildly — exactly the irregular message-driven pattern
//! the paper's strategies target. The family declares a CPU fallback, so
//! the dynamic hybrid scheduler (section 3.3) splits its bursts across
//! the CPU pool and the GPU using rates learned *for this family*,
//! independent of any other registered kind.
//!
//! Per iteration, row chare i computes y_i = sum_j A_ij x_j by submitting
//! one work request per [`SPMV_TILE`]-entry chunk of its row (each tile
//! packs `[a_ij, x_j]` pairs), folds the partial dot products, applies a
//! weighted-Jacobi update x_i += omega (b_i - y_i) / A_ii, and contributes
//! the squared residual to the iteration reduction.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::{
    Chare, ChareId, Config, Ctx, JobSpec, KernelDescriptor, KernelKindId,
    Msg, Report, Runtime, Tile, WorkDraft, WrResult, METHOD_RESULT,
};
use crate::runtime::kernel::{TileArgSpec, TileKernel};
use crate::runtime::KernelResources;
use crate::util::Rng;

/// Chare collection id of row chares.
pub const SPMV_COLLECTION: u32 = 3;

/// Row entries per work-request tile (`[coef, x]` pairs).
pub const SPMV_TILE: usize = 128;

/// Entry method id: begin one Jacobi sweep.
pub const METHOD_SWEEP: u32 = 1;

/// Per-slot kernel: dot product of the packed `[coef, x]` pairs. Padding
/// pairs are zero, so they contribute nothing.
fn spmv_slot(args: &[&[f32]], _constant: &[f32]) -> Vec<f32> {
    let entries = args[0];
    let mut acc = 0.0f32;
    for pair in entries.chunks_exact(2) {
        acc += pair[0] * pair[1];
    }
    vec![acc]
}

/// The `spmv_row` kernel family, built entirely from public types: one
/// `SPMV_TILE x 2` input tile, a 1x1 output, a CPU fallback for hybrid
/// scheduling, no reuse (x changes every sweep).
pub fn spmv_descriptor() -> KernelDescriptor {
    KernelDescriptor {
        kernel: Arc::new(TileKernel {
            name: Arc::from("spmv_row"),
            args: vec![TileArgSpec {
                name: "entries",
                rows: SPMV_TILE,
                width: 2,
                pad: 0.0,
            }],
            constant: Arc::new(Vec::new()),
            out_rows: 1,
            out_width: 1,
            resources: KernelResources {
                threads_per_block: 128,
                regs_per_thread: 32,
                smem_per_block: 1024,
            },
            items_per_slot: SPMV_TILE as u64,
            reuse_arg: None,
            gather_name: None,
            entry_arg: None,
            slot_fn: spmv_slot,
        }),
        combine: None,
        sort_by_slot: false,
        cpu_fallback: true,
        launch_mode: None,
    }
}

/// SpMV experiment configuration.
#[derive(Debug, Clone)]
pub struct SpmvConfig {
    /// Matrix dimension (rows == cols); one chare per row.
    pub rows: usize,
    /// Heavy-tail cap on off-diagonal entries per row.
    pub max_row_nnz: usize,
    /// Jacobi sweeps to run.
    pub iters: usize,
    /// Weighted-Jacobi relaxation factor.
    pub omega: f64,
    pub seed: u64,
    pub runtime: Config,
}

impl SpmvConfig {
    pub fn new(rows: usize) -> SpmvConfig {
        SpmvConfig {
            rows,
            max_row_nnz: 512,
            iters: 5,
            omega: 0.8,
            seed: 7,
            runtime: Config::default(),
        }
    }
}

/// Outcome of an SpMV run.
#[derive(Debug)]
pub struct SpmvResult {
    pub report: Report,
    pub wall: f64,
    /// Squared residual norm ||b - A x||^2 per sweep.
    pub residuals: Vec<f64>,
    pub rows: usize,
}

/// One CSR row of the synthetic diagonally dominant matrix.
#[derive(Debug, Clone)]
pub struct CsrRow {
    /// Off-diagonal column indices.
    pub cols: Vec<u32>,
    /// Off-diagonal coefficients (aligned with `cols`).
    pub vals: Vec<f32>,
    /// Diagonal coefficient (dominant: > sum |off-diagonal|).
    pub diag: f32,
}

/// Synthetic CSR matrix with wildly varying row lengths: row nnz follows
/// a cubed-uniform (heavy-tailed) distribution in `[0, max_nnz]`, columns
/// are uniform, and the diagonal dominates so Jacobi converges.
pub fn generate_matrix(rows: usize, max_nnz: usize, seed: u64) -> Vec<CsrRow> {
    let mut rng = Rng::new(seed);
    (0..rows)
        .map(|_| {
            let u = rng.f64();
            let nnz = ((u * u * u) * max_nnz as f64) as usize;
            let cols: Vec<u32> =
                (0..nnz).map(|_| rng.below(rows) as u32).collect();
            let vals: Vec<f32> =
                (0..nnz).map(|_| rng.range(-1.0, 1.0) as f32).collect();
            let dominance: f32 =
                vals.iter().map(|v| v.abs()).sum::<f32>() + 1.0;
            CsrRow { cols, vals, diag: dominance }
        })
        .collect()
}

/// Driver -> row chare: run one sweep against the snapshot `x`. Carries
/// the resolved `spmv_row` kind (assigned by the shared registry at
/// submission).
struct SweepMsg {
    x: Arc<Vec<f32>>,
    kind: KernelKindId,
}

/// One matrix row as a chare: submits tile requests, folds partial dot
/// products, applies the Jacobi update, contributes its residual.
struct RowChare {
    id: ChareId,
    kind: KernelKindId,
    row: CsrRow,
    b: f32,
    omega: f64,
    master: Arc<Mutex<Vec<f32>>>,
    pending: usize,
    acc: f64,
    /// x_i and the diagonal contribution captured at sweep start.
    x_snapshot: f32,
}

impl RowChare {
    fn finish(&mut self, ctx: &mut Ctx) {
        // y_i = diag * x_i + off-diagonal partials
        let y = self.row.diag as f64 * self.x_snapshot as f64 + self.acc;
        let r = self.b as f64 - y;
        {
            let mut x = self.master.lock().unwrap();
            let xi = &mut x[self.id.index as usize];
            *xi += (self.omega * r / self.row.diag as f64) as f32;
        }
        ctx.contribute(r * r);
    }
}

impl Chare for RowChare {
    fn receive(&mut self, msg: Msg, ctx: &mut Ctx) {
        match msg.method {
            METHOD_SWEEP => {
                let m: SweepMsg = msg.take();
                self.kind = m.kind;
                self.pending = 0;
                self.acc = 0.0;
                self.x_snapshot = m.x[self.id.index as usize];
                for (chunk_c, chunk_v) in self
                    .row
                    .cols
                    .chunks(SPMV_TILE)
                    .zip(self.row.vals.chunks(SPMV_TILE))
                {
                    let mut entries = vec![0.0f32; SPMV_TILE * 2];
                    for (k, (&c, &v)) in
                        chunk_c.iter().zip(chunk_v).enumerate()
                    {
                        entries[k * 2] = v;
                        entries[k * 2 + 1] = m.x[c as usize];
                    }
                    ctx.submit(WorkDraft {
                        chare: self.id,
                        kind: self.kind,
                        buffer: None,
                        data_items: chunk_c.len().max(1),
                        tag: 0,
                        payload: Tile::new(vec![entries]),
                    })
                    .expect("canonical spmv tile shape");
                    self.pending += 1;
                }
                if self.pending == 0 {
                    self.finish(ctx);
                }
            }
            METHOD_RESULT => {
                let r: WrResult = msg.take();
                self.acc += r.out[0] as f64;
                self.pending -= 1;
                if self.pending == 0 {
                    self.finish(ctx);
                }
            }
            other => panic!("RowChare: unknown method {other}"),
        }
    }
}

/// Build the SpMV workload as a [`JobSpec`]: row chares over the
/// synthetic matrix, the `spmv_row` family registration, and a driver
/// pacing `cfg.iters` Jacobi sweeps. The driver's series is the squared
/// residual per sweep. `master` is the shared iterate `x` (exposed so
/// tests can compare final vectors bitwise across runtimes).
pub fn job_spec_with_master(
    cfg: &SpmvConfig,
    name: &str,
    master: Arc<Mutex<Vec<f32>>>,
) -> JobSpec {
    let matrix = generate_matrix(cfg.rows, cfg.max_row_nnz, cfg.seed);
    let mut spec = JobSpec::new(name).kernel(spmv_descriptor());
    for (i, row) in matrix.into_iter().enumerate() {
        let id = ChareId::new(SPMV_COLLECTION, i as u32);
        spec = spec.chare(
            id,
            i,
            Box::new(RowChare {
                id,
                kind: KernelKindId(0), // real id arrives with each sweep
                row,
                b: 1.0,
                omega: cfg.omega,
                master: master.clone(),
                pending: 0,
                acc: 0.0,
                x_snapshot: 0.0,
            }),
        );
    }
    let rows = cfg.rows;
    let iters = cfg.iters;
    spec.driver(move |ctx| {
        let kind = ctx.kinds()[0];
        let mut residuals = Vec::with_capacity(iters);
        for _ in 0..iters {
            let x: Arc<Vec<f32>> =
                Arc::new(master.lock().unwrap().clone());
            for i in 0..rows {
                ctx.send(
                    ChareId::new(SPMV_COLLECTION, i as u32),
                    Msg::new(
                        METHOD_SWEEP,
                        SweepMsg { x: x.clone(), kind },
                    ),
                );
            }
            residuals.push(ctx.await_reduction(rows as u64)?);
            ctx.await_quiescence();
        }
        Ok(residuals)
    })
}

/// [`job_spec_with_master`] with a private iterate.
pub fn job_spec(cfg: &SpmvConfig) -> JobSpec {
    job_spec_with_master(
        cfg,
        "spmv",
        Arc::new(Mutex::new(vec![0.0f32; cfg.rows])),
    )
}

/// Run weighted-Jacobi sweeps of `x <- x + omega D^-1 (b - A x)` with
/// b = 1, x0 = 0, as a single job on a private runtime.
pub fn run(cfg: &SpmvConfig) -> Result<SpmvResult> {
    let rt = Runtime::new(cfg.runtime.clone())?;
    let t0 = Instant::now();
    let handle = rt.submit_job(job_spec(cfg))?;
    let job = handle.wait()?;
    let wall = t0.elapsed().as_secs_f64();
    let mut report = rt.shutdown();
    report.total_wall = wall;
    Ok(SpmvResult {
        report,
        wall,
        residuals: job.series,
        rows: cfg.rows,
    })
}

/// Reference sweep on plain loops (f64): the physics oracle for tests.
pub fn reference_residuals(cfg: &SpmvConfig) -> Vec<f64> {
    let matrix = generate_matrix(cfg.rows, cfg.max_row_nnz, cfg.seed);
    let mut x = vec![0.0f64; cfg.rows];
    let mut out = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let snap = x.clone();
        let mut total = 0.0f64;
        for (i, row) in matrix.iter().enumerate() {
            let mut y = row.diag as f64 * snap[i];
            for (&c, &v) in row.cols.iter().zip(&row.vals) {
                y += v as f64 * snap[c as usize];
            }
            let r = 1.0 - y;
            x[i] += cfg.omega * r / row.diag as f64;
            total += r * r;
        }
        out.push(total);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_rows_are_heavy_tailed_and_dominant() {
        let m = generate_matrix(400, 256, 3);
        assert_eq!(m.len(), 400);
        let lens: Vec<usize> = m.iter().map(|r| r.cols.len()).collect();
        let max = *lens.iter().max().unwrap();
        let mean = lens.iter().sum::<usize>() / lens.len();
        assert!(max > 4 * mean.max(1), "row lengths should vary wildly");
        for r in &m {
            let off: f32 = r.vals.iter().map(|v| v.abs()).sum();
            assert!(r.diag > off, "diagonal must dominate");
        }
    }

    #[test]
    fn slot_fn_computes_dot_product() {
        let entries = [2.0f32, 3.0, 0.5, 4.0, 0.0, 9.0];
        let out = spmv_slot(&[&entries], &[]);
        assert_eq!(out, vec![8.0]);
    }

    #[test]
    fn descriptor_is_registrable() {
        let mut reg = crate::coordinator::KernelRegistry::new();
        let id = reg.register(spmv_descriptor()).unwrap();
        assert_eq!(reg.kernel(id).max_combine(), 208);
    }

    #[test]
    fn reference_residuals_decrease() {
        let cfg = SpmvConfig { iters: 4, ..SpmvConfig::new(200) };
        let r = reference_residuals(&cfg);
        assert_eq!(r.len(), 4);
        assert!(r[3] < r[0], "Jacobi must converge on a dominant matrix");
    }
}
