//! Patch chares for the 2D molecular dynamics app (paper section 4.2).
//!
//! The 2D box is partitioned into patches; each patch owns the particles in
//! its region. Per timestep a patch: (1) shares its particle coordinates
//! with its 8 neighbors (torus topology), (2) submits one MdInteract work
//! request per (my-chunk x their-chunk) pair as buffers arrive -- the
//! *compute object* of the Charm++/NAMD scheme, (3) folds returned forces,
//! integrates, and (4) migrates departing particles to neighbors, then
//! contributes kinetic energy to the step reduction.
//!
//! Patch populations vary (clustered initialization) and chunking makes
//! request workloads uneven -- the irregularity Fig 5's adaptive hybrid
//! scheduling exploits.

use std::sync::Arc;

use crate::coordinator::{
    Chare, ChareId, Ctx, KernelKindId, Msg, Tile, WorkDraft, WrResult,
    METHOD_RESULT,
};
use crate::runtime::shapes::{MD_PAD_POS, MD_W, PARTS_PER_PATCH};

/// Entry methods.
pub const METHOD_STEP: u32 = 1;
pub const METHOD_SHARE: u32 = 2;
pub const METHOD_MIGRATE: u32 = 3;

/// One MD particle (host state in f64).
#[derive(Debug, Clone, Copy)]
pub struct MdParticle {
    pub pos: [f64; 2],
    pub vel: [f64; 2],
}

/// Driver -> patch: begin one timestep. Carries the resolved MD kernel
/// kind: the job's driver learns it from `JobCtx::kinds` (ids are
/// assigned by the shared registry at submission, after the chare set is
/// built).
pub struct StepMsg {
    pub dt: f64,
    pub kind: KernelKindId,
}

/// Patch -> patch: padded particle chunks for force computation.
pub struct ShareMsg {
    pub from: u32,
    /// Padded f32 chunks (PARTS_PER_PATCH x 2 each).
    pub chunks: Arc<Vec<Vec<f32>>>,
}

/// Patch -> patch: particles that crossed into the receiver's region.
pub struct MigrateMsg {
    pub parts: Vec<MdParticle>,
}

/// Static patch geometry/physics.
#[derive(Debug, Clone, Copy)]
pub struct PatchParams {
    pub grid: usize,
    pub box_l: f64,
}

/// The Patch chare.
pub struct Patch {
    id: ChareId,
    gx: usize,
    gy: usize,
    p: PatchParams,
    /// Registered MD interact kernel kind (from
    /// `GCharm::register_kernel`).
    md_kind: KernelKindId,
    particles: Vec<MdParticle>,

    // per-step state
    started: bool,
    dt: f64,
    my_chunks: Arc<Vec<Vec<f32>>>,
    chunk_counts: Vec<usize>,
    forces: Vec<[f64; 2]>,
    shares_received: usize,
    early_shares: Vec<ShareMsg>,
    expected_results: usize,
    received_results: usize,
    integrated: bool,
    migrations_received: usize,
    arrivals: Vec<MdParticle>,
}

impl Patch {
    pub fn new(
        id: ChareId,
        gx: usize,
        gy: usize,
        p: PatchParams,
        md_kind: KernelKindId,
        particles: Vec<MdParticle>,
    ) -> Patch {
        Patch {
            id,
            gx,
            gy,
            p,
            md_kind,
            particles,
            started: false,
            dt: 0.0,
            my_chunks: Arc::new(Vec::new()),
            chunk_counts: Vec::new(),
            forces: Vec::new(),
            shares_received: 0,
            early_shares: Vec::new(),
            expected_results: 0,
            received_results: 0,
            integrated: false,
            migrations_received: 0,
            arrivals: Vec::new(),
        }
    }

    fn neighbor_ids(&self) -> Vec<(ChareId, i32, i32)> {
        let g = self.p.grid as i32;
        let mut out = Vec::with_capacity(8);
        for dy in -1..=1 {
            for dx in -1..=1 {
                if dx == 0 && dy == 0 {
                    continue;
                }
                let nx = (self.gx as i32 + dx).rem_euclid(g);
                let ny = (self.gy as i32 + dy).rem_euclid(g);
                out.push((
                    ChareId::new(
                        self.id.collection,
                        (ny * g + nx) as u32,
                    ),
                    dx,
                    dy,
                ));
            }
        }
        out
    }

    /// Pad this patch's particles into PARTS_PER_PATCH-sized f32 chunks.
    fn build_chunks(&mut self) {
        let mut chunks = Vec::new();
        self.chunk_counts.clear();
        for group in self.particles.chunks(PARTS_PER_PATCH) {
            let mut c = vec![MD_PAD_POS; PARTS_PER_PATCH * MD_W];
            for (j, q) in group.iter().enumerate() {
                c[j * MD_W] = q.pos[0] as f32;
                c[j * MD_W + 1] = q.pos[1] as f32;
            }
            chunks.push(c);
            self.chunk_counts.push(group.len());
        }
        self.my_chunks = Arc::new(chunks);
    }

    fn on_step(&mut self, m: StepMsg, ctx: &mut Ctx) {
        assert!(!self.started, "step already in flight");
        self.started = true;
        self.dt = m.dt;
        self.md_kind = m.kind;
        self.forces = vec![[0.0; 2]; self.particles.len()];
        self.build_chunks();

        // broadcast my chunks to the 8 neighbors
        for (nid, _, _) in self.neighbor_ids() {
            ctx.send(
                nid,
                Msg::new(
                    METHOD_SHARE,
                    ShareMsg {
                        from: self.id.index,
                        chunks: self.my_chunks.clone(),
                    },
                ),
            );
        }

        // self-interaction counts as the 9th share
        let self_share =
            ShareMsg { from: self.id.index, chunks: self.my_chunks.clone() };
        self.process_share(self_share, ctx);

        // replay shares that arrived before our STEP
        let early = std::mem::take(&mut self.early_shares);
        for s in early {
            self.process_share(s, ctx);
        }
        self.maybe_finish(ctx);
    }

    /// Wrap-shift for a sender at grid delta (their frame -> mine).
    fn wrap_shift(&self, from: u32) -> (f32, f32) {
        let g = self.p.grid as i32;
        let fx = (from as i32) % g;
        let fy = (from as i32) / g;
        let l = self.p.box_l as f32;
        let d = |a: i32, b: i32| -> f32 {
            let raw = a - b;
            if raw > 1 {
                -l // sender wrapped around the high edge
            } else if raw < -1 {
                l
            } else {
                0.0
            }
        };
        (d(fx, self.gx as i32), d(fy, self.gy as i32))
    }

    fn process_share(&mut self, s: ShareMsg, ctx: &mut Ctx) {
        self.shares_received += 1;
        if self.my_chunks.is_empty() || s.chunks.is_empty() {
            return;
        }
        let (sx, sy) = if s.from == self.id.index {
            (0.0, 0.0)
        } else {
            self.wrap_shift(s.from)
        };
        for (ci, mine) in self.my_chunks.iter().enumerate() {
            let my_count = self.chunk_counts[ci];
            for theirs in s.chunks.iter() {
                let mut pb = theirs.clone();
                if sx != 0.0 || sy != 0.0 {
                    for r in 0..PARTS_PER_PATCH {
                        if pb[r * MD_W] < MD_PAD_POS / 2.0 {
                            pb[r * MD_W] += sx;
                            pb[r * MD_W + 1] += sy;
                        }
                    }
                }
                let their_count =
                    pb.chunks(MD_W).filter(|r| r[0] < MD_PAD_POS / 2.0).count();
                // Workload model (section 3.3): the pairwise interact cost
                // scales with the *product* of the two particle counts --
                // this is the per-request weight the adaptive split uses
                // and the static count-split ignores.
                ctx.submit(WorkDraft {
                    chare: self.id,
                    kind: self.md_kind,
                    buffer: None,
                    data_items: (my_count * their_count).max(1),
                    tag: ci as u64,
                    payload: Tile::new(vec![mine.clone(), pb]),
                })
                .expect("canonical md tile shapes");
                self.expected_results += 1;
            }
        }
    }

    fn on_result(&mut self, r: WrResult, ctx: &mut Ctx) {
        let ci = r.tag as usize;
        let base = ci * PARTS_PER_PATCH;
        for j in 0..self.chunk_counts[ci] {
            self.forces[base + j][0] += r.out[j * MD_W] as f64;
            self.forces[base + j][1] += r.out[j * MD_W + 1] as f64;
        }
        self.received_results += 1;
        self.maybe_finish(ctx);
    }

    /// Integrate + start migration once all shares and results are in.
    fn maybe_finish(&mut self, ctx: &mut Ctx) {
        if !self.started
            || self.integrated
            || self.shares_received < 9
            || self.received_results < self.expected_results
        {
            return;
        }
        self.integrated = true;

        // velocity Verlet (single-force variant): v += f dt; x += v dt
        let l = self.p.box_l;
        for (q, f) in self.particles.iter_mut().zip(&self.forces) {
            q.vel[0] += f[0] * self.dt;
            q.vel[1] += f[1] * self.dt;
            q.pos[0] = (q.pos[0] + q.vel[0] * self.dt).rem_euclid(l);
            q.pos[1] = (q.pos[1] + q.vel[1] * self.dt).rem_euclid(l);
        }

        // partition stayers vs leavers
        let g = self.p.grid;
        let patch_l = l / g as f64;
        let mut out: Vec<Vec<MdParticle>> = vec![Vec::new(); 8];
        let neighbors = self.neighbor_ids();
        let mut staying = Vec::with_capacity(self.particles.len());
        for q in self.particles.drain(..) {
            let tx = ((q.pos[0] / patch_l) as usize).min(g - 1);
            let ty = ((q.pos[1] / patch_l) as usize).min(g - 1);
            if tx == self.gx && ty == self.gy {
                staying.push(q);
            } else {
                // direction sign picks the neighbor slot
                let slot = neighbors
                    .iter()
                    .position(|&(nid, _, _)| {
                        let ngx = (nid.index as usize) % g;
                        let ngy = (nid.index as usize) / g;
                        ngx == tx && ngy == ty
                    })
                    .unwrap_or_else(|| {
                        // crossed more than one patch in a step (dt too
                        // large): hand to the nearest neighbor in that
                        // direction, it will forward next step
                        let dxs = wrap_dir(self.gx, tx, g);
                        let dys = wrap_dir(self.gy, ty, g);
                        neighbors
                            .iter()
                            .position(|&(_, dx, dy)| dx == dxs && dy == dys)
                            .expect("direction neighbor exists")
                    });
                out[slot].push(q);
            }
        }
        self.particles = staying;
        for ((nid, _, _), parts) in neighbors.into_iter().zip(out) {
            ctx.send(nid, Msg::new(METHOD_MIGRATE, MigrateMsg { parts }));
        }
        self.maybe_contribute(ctx);
    }

    fn on_migrate(&mut self, m: MigrateMsg, ctx: &mut Ctx) {
        self.migrations_received += 1;
        self.arrivals.extend(m.parts);
        self.maybe_contribute(ctx);
    }

    /// Step is complete when we integrated and heard from all 8 neighbors.
    fn maybe_contribute(&mut self, ctx: &mut Ctx) {
        if !self.integrated || self.migrations_received < 8 {
            return;
        }
        self.particles.append(&mut self.arrivals);
        let ke: f64 = self
            .particles
            .iter()
            .map(|q| 0.5 * (q.vel[0] * q.vel[0] + q.vel[1] * q.vel[1]))
            .sum();
        // reset per-step state
        self.started = false;
        self.integrated = false;
        self.shares_received = 0;
        self.expected_results = 0;
        self.received_results = 0;
        self.migrations_received = 0;
        ctx.contribute(ke);
    }
}

fn wrap_dir(from: usize, to: usize, g: usize) -> i32 {
    let raw = to as i32 - from as i32;
    if raw == 0 {
        0
    } else if raw.rem_euclid(g as i32) <= g as i32 / 2 {
        1
    } else {
        -1
    }
}

impl Chare for Patch {
    fn receive(&mut self, msg: Msg, ctx: &mut Ctx) {
        match msg.method {
            METHOD_STEP => {
                let m: StepMsg = msg.take();
                self.on_step(m, ctx);
            }
            METHOD_SHARE => {
                let m: ShareMsg = msg.take();
                if self.started {
                    self.process_share(m, ctx);
                    self.maybe_finish(ctx);
                } else {
                    self.early_shares.push(m);
                }
            }
            METHOD_MIGRATE => {
                let m: MigrateMsg = msg.take();
                self.on_migrate(m, ctx);
            }
            METHOD_RESULT => {
                let r: WrResult = msg.take();
                self.on_result(r, ctx);
            }
            other => panic!("Patch: unknown method {other}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn patch(gx: usize, gy: usize, grid: usize) -> Patch {
        Patch::new(
            ChareId::new(2, (gy * grid + gx) as u32),
            gx,
            gy,
            PatchParams { grid, box_l: 8.0 },
            KernelKindId(0),
            Vec::new(),
        )
    }

    #[test]
    fn eight_distinct_neighbors() {
        let p = patch(1, 1, 4);
        let ns = p.neighbor_ids();
        assert_eq!(ns.len(), 8);
        let mut ids: Vec<u32> = ns.iter().map(|&(n, _, _)| n.index).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 8);
    }

    #[test]
    fn corner_patch_wraps_torus() {
        let p = patch(0, 0, 4);
        let ns = p.neighbor_ids();
        // the (-1,-1) neighbor of (0,0) is (3,3) = index 15
        assert!(ns.iter().any(|&(n, dx, dy)| dx == -1 && dy == -1 && n.index == 15));
    }

    #[test]
    fn wrap_shift_signs() {
        let g = 4;
        let me = patch(3, 0, g); // high-x edge
        // neighbor at gx = 0 (wrapped +x side): its coords must shift +L
        let from = 0u32; // (0, 0)
        let (sx, sy) = me.wrap_shift(from);
        assert_eq!(sx, 8.0);
        assert_eq!(sy, 0.0);
        // interior neighbor (2, 0): no shift
        let (sx, _) = me.wrap_shift(2);
        assert_eq!(sx, 0.0);
    }

    #[test]
    fn chunking_pads_and_counts() {
        let mut p = patch(0, 0, 4);
        p.particles = (0..70)
            .map(|i| MdParticle {
                pos: [i as f64 * 0.01, 0.5],
                vel: [0.0, 0.0],
            })
            .collect();
        p.build_chunks();
        assert_eq!(p.my_chunks.len(), 2);
        assert_eq!(p.chunk_counts, vec![PARTS_PER_PATCH, 6]);
        // padding rows parked far away
        let c1 = &p.my_chunks[1];
        assert_eq!(c1[6 * MD_W], MD_PAD_POS);
    }

    #[test]
    fn wrap_dir_chooses_shortest_way() {
        assert_eq!(wrap_dir(0, 1, 8), 1);
        assert_eq!(wrap_dir(1, 0, 8), -1);
        assert_eq!(wrap_dir(0, 7, 8), -1); // wrap back
        assert_eq!(wrap_dir(7, 0, 8), 1); // wrap forward
        assert_eq!(wrap_dir(3, 3, 8), 0);
    }
}
