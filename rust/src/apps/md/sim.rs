//! MD simulation drivers (paper sections 4.2, 4.6 / Fig 5).
//!
//! [`run`] executes the patch-chare simulation on the G-Charm runtime with
//! hybrid CPU+GPU scheduling (the Fig 5 experiment: static count-split vs
//! adaptive data-item split). [`run_single_core_cpu`] is the paper's
//! "single-core CPU implementation" baseline: the same physics, straight
//! nested loops on one thread.

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::cpu_kernels::cpu_md_interact;
use crate::coordinator::{
    md_descriptor, ChareId, Config, JobSpec, Msg, Report, Runtime,
};
use crate::runtime::shapes::{MD_PAD_POS, MD_W, PARTS_PER_PATCH};
use crate::util::Rng;

use super::patch::{
    MdParticle, Patch, PatchParams, StepMsg, METHOD_STEP,
};

/// Chare collection id of Patches.
pub const MD_COLLECTION: u32 = 2;

/// MD experiment configuration.
#[derive(Debug, Clone)]
pub struct MdConfig {
    pub n_particles: usize,
    /// Patch grid is `grid x grid`.
    pub grid: usize,
    pub box_l: f64,
    pub steps: usize,
    pub dt: f64,
    /// LJ cutoff radius; patch side must be >= rc.
    pub rc: f64,
    pub sigma: f64,
    pub eps_lj: f64,
    /// Gaussian-blob initialization (irregular patch populations).
    pub clustered: bool,
    pub seed: u64,
    pub runtime: Config,
}

impl MdConfig {
    /// Box and grid auto-scale with `n_particles` to keep the mean density
    /// near 8 particles per unit area (typical spacing ~0.35 > sigma, so
    /// the LJ dynamics stay stable) with patch side 2.0 >= cutoff.
    pub fn new(n_particles: usize) -> MdConfig {
        let target_box = (n_particles as f64 / 8.0).sqrt().max(8.0);
        let grid = ((target_box / 2.0).floor() as usize).max(4);
        MdConfig {
            n_particles,
            grid,
            box_l: grid as f64 * 2.0,
            steps: 10,
            dt: 2e-4,
            rc: 1.0,
            sigma: 0.2,
            eps_lj: 1.0,
            clustered: true,
            seed: 42,
            runtime: Config::default(),
        }
    }

    pub fn md_params(&self) -> [f32; 3] {
        [
            (self.rc * self.rc) as f32,
            (self.sigma * self.sigma) as f32,
            self.eps_lj as f32,
        ]
    }

    /// Initial particle set.
    pub fn generate(&self) -> Vec<MdParticle> {
        let mut rng = Rng::new(self.seed);
        let mut out = Vec::with_capacity(self.n_particles);
        if !self.clustered {
            for _ in 0..self.n_particles {
                out.push(MdParticle {
                    pos: [
                        rng.range(0.0, self.box_l),
                        rng.range(0.0, self.box_l),
                    ],
                    vel: [rng.normal() * 0.05, rng.normal() * 0.05],
                });
            }
            return out;
        }
        // Gaussian blobs with uneven populations
        let nblobs = 6;
        let centers: Vec<[f64; 2]> = (0..nblobs)
            .map(|_| {
                [
                    rng.range(0.15, 0.85) * self.box_l,
                    rng.range(0.15, 0.85) * self.box_l,
                ]
            })
            .collect();
        for i in 0..self.n_particles {
            let c = centers[(i * i + i / 3) % nblobs];
            let spread = self.box_l * 0.08;
            out.push(MdParticle {
                pos: [
                    (c[0] + rng.normal() * spread).rem_euclid(self.box_l),
                    (c[1] + rng.normal() * spread).rem_euclid(self.box_l),
                ],
                vel: [rng.normal() * 0.05, rng.normal() * 0.05],
            });
        }
        out
    }
}

/// Outcome of an MD run.
#[derive(Debug)]
pub struct MdResult {
    pub report: Report,
    pub wall: f64,
    /// Kinetic energy per step (reduction values).
    pub energies: Vec<f64>,
    pub patches: usize,
}

/// Assign particles to their owning patch.
fn bin_particles(
    parts: Vec<MdParticle>,
    grid: usize,
    box_l: f64,
) -> Vec<Vec<MdParticle>> {
    let mut bins = vec![Vec::new(); grid * grid];
    let patch_l = box_l / grid as f64;
    for q in parts {
        let gx = ((q.pos[0] / patch_l) as usize).min(grid - 1);
        let gy = ((q.pos[1] / patch_l) as usize).min(grid - 1);
        bins[gy * grid + gx].push(q);
    }
    bins
}

/// Build the MD workload as a [`JobSpec`] for a (possibly shared)
/// [`Runtime`]: the patch-chare set, the `md_force` family registration,
/// and a driver pacing `cfg.steps` timesteps. The driver's series is the
/// per-step kinetic energy.
pub fn job_spec(cfg: &MdConfig) -> Result<JobSpec> {
    job_spec_named(cfg, "md")
}

/// [`job_spec`] under an explicit job name (mixed-workload serving
/// submits several instances).
pub fn job_spec_named(cfg: &MdConfig, name: &str) -> Result<JobSpec> {
    anyhow::ensure!(
        cfg.box_l / cfg.grid as f64 >= cfg.rc,
        "patch side must be >= cutoff"
    );
    let bins = bin_particles(cfg.generate(), cfg.grid, cfg.box_l);
    let npatches = cfg.grid * cfg.grid;
    let params = PatchParams { grid: cfg.grid, box_l: cfg.box_l };

    let mut spec =
        JobSpec::new(name).kernel(md_descriptor(cfg.md_params()));
    for (i, bin) in bins.into_iter().enumerate() {
        let id = ChareId::new(MD_COLLECTION, i as u32);
        let gx = i % cfg.grid;
        let gy = i / cfg.grid;
        spec = spec.chare(
            id,
            i,
            // the real kind id arrives with each StepMsg, resolved by
            // the driver from the shared registry
            Box::new(Patch::new(
                id,
                gx,
                gy,
                params,
                crate::coordinator::KernelKindId(0),
                bin,
            )),
        );
    }

    let steps = cfg.steps;
    let dt = cfg.dt;
    Ok(spec.driver(move |ctx| {
        let md_kind = ctx.kinds()[0];
        let mut energies = Vec::with_capacity(steps);
        for _ in 0..steps {
            for i in 0..npatches {
                ctx.send(
                    ChareId::new(MD_COLLECTION, i as u32),
                    Msg::new(
                        METHOD_STEP,
                        StepMsg { dt, kind: md_kind },
                    ),
                );
            }
            energies.push(ctx.await_reduction(npatches as u64)?);
            ctx.await_quiescence();
        }
        Ok(energies)
    }))
}

/// Run the MD simulation as a single job on a private runtime.
pub fn run(cfg: &MdConfig) -> Result<MdResult> {
    let npatches = cfg.grid * cfg.grid;
    let rt = Runtime::new(cfg.runtime.clone())?;
    let t0 = Instant::now();
    let handle = rt.submit_job(job_spec(cfg)?)?;
    let job = handle.wait()?;
    let wall = t0.elapsed().as_secs_f64();
    let mut report = rt.shutdown();
    report.total_wall = wall;
    Ok(MdResult { report, wall, energies: job.series, patches: npatches })
}

/// Single-core CPU baseline: same physics, plain loops, one thread.
pub fn run_single_core_cpu(cfg: &MdConfig) -> MdResult {
    let grid = cfg.grid;
    let mut bins = bin_particles(cfg.generate(), grid, cfg.box_l);
    let params = cfg.md_params();
    let patch_l = cfg.box_l / grid as f64;

    let pad = |bin: &[MdParticle]| -> Vec<Vec<f32>> {
        bin.chunks(PARTS_PER_PATCH)
            .map(|group| {
                let mut c = vec![MD_PAD_POS; PARTS_PER_PATCH * MD_W];
                for (j, q) in group.iter().enumerate() {
                    c[j * MD_W] = q.pos[0] as f32;
                    c[j * MD_W + 1] = q.pos[1] as f32;
                }
                c
            })
            .collect()
    };

    let t0 = Instant::now();
    let mut energies = Vec::with_capacity(cfg.steps);
    for _ in 0..cfg.steps {
        let chunks: Vec<Vec<Vec<f32>>> = bins.iter().map(|b| pad(b)).collect();
        let mut forces: Vec<Vec<[f64; 2]>> =
            bins.iter().map(|b| vec![[0.0; 2]; b.len()]).collect();

        for gy in 0..grid {
            for gx in 0..grid {
                let me = gy * grid + gx;
                for dy in -1i32..=1 {
                    for dx in -1i32..=1 {
                        let nx = (gx as i32 + dx).rem_euclid(grid as i32) as usize;
                        let ny = (gy as i32 + dy).rem_euclid(grid as i32) as usize;
                        let nb = ny * grid + nx;
                        let (sx, sy) = (
                            if gx as i32 + dx < 0 {
                                -cfg.box_l as f32
                            } else if gx as i32 + dx >= grid as i32 {
                                cfg.box_l as f32
                            } else {
                                0.0
                            },
                            if gy as i32 + dy < 0 {
                                -cfg.box_l as f32
                            } else if gy as i32 + dy >= grid as i32 {
                                cfg.box_l as f32
                            } else {
                                0.0
                            },
                        );
                        for (ci, mine) in chunks[me].iter().enumerate() {
                            for theirs in &chunks[nb] {
                                let mut pb = theirs.clone();
                                if sx != 0.0 || sy != 0.0 {
                                    for r in 0..PARTS_PER_PATCH {
                                        if pb[r * MD_W] < MD_PAD_POS / 2.0 {
                                            pb[r * MD_W] += sx;
                                            pb[r * MD_W + 1] += sy;
                                        }
                                    }
                                }
                                let out = cpu_md_interact(mine, &pb, params);
                                let base = ci * PARTS_PER_PATCH;
                                let count = bins[me]
                                    .len()
                                    .saturating_sub(base)
                                    .min(PARTS_PER_PATCH);
                                for j in 0..count {
                                    forces[me][base + j][0] +=
                                        out[j * MD_W] as f64;
                                    forces[me][base + j][1] +=
                                        out[j * MD_W + 1] as f64;
                                }
                            }
                        }
                    }
                }
            }
        }

        // integrate + rebin
        let mut ke = 0.0f64;
        let mut all = Vec::new();
        for (bin, fs) in bins.iter_mut().zip(&forces) {
            for (q, f) in bin.iter_mut().zip(fs) {
                q.vel[0] += f[0] * cfg.dt;
                q.vel[1] += f[1] * cfg.dt;
                q.pos[0] = (q.pos[0] + q.vel[0] * cfg.dt).rem_euclid(cfg.box_l);
                q.pos[1] = (q.pos[1] + q.vel[1] * cfg.dt).rem_euclid(cfg.box_l);
                ke += 0.5 * (q.vel[0] * q.vel[0] + q.vel[1] * q.vel[1]);
            }
            all.append(bin);
        }
        let _ = patch_l;
        bins = bin_particles(all, grid, cfg.box_l);
        energies.push(ke);
    }
    let wall = t0.elapsed().as_secs_f64();
    let mut report = Report::default();
    report.total_wall = wall;
    MdResult { report, wall, energies, patches: grid * grid }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binning_conserves_particles() {
        let cfg = MdConfig::new(1000);
        let bins = bin_particles(cfg.generate(), cfg.grid, cfg.box_l);
        let total: usize = bins.iter().map(|b| b.len()).sum();
        assert_eq!(total, 1000);
    }

    #[test]
    fn clustered_bins_are_uneven() {
        let cfg = MdConfig::new(2000);
        let bins = bin_particles(cfg.generate(), cfg.grid, cfg.box_l);
        let max = bins.iter().map(|b| b.len()).max().unwrap();
        let mean = 2000 / bins.len();
        assert!(max > 2 * mean, "clustering should overload some patches");
    }

    #[test]
    fn uniform_bins_are_even_ish() {
        let cfg = MdConfig { clustered: false, ..MdConfig::new(6400) };
        let bins = bin_particles(cfg.generate(), cfg.grid, cfg.box_l);
        let max = bins.iter().map(|b| b.len()).max().unwrap();
        let mean = 6400 / bins.len();
        assert!(max < 2 * mean);
    }

    #[test]
    fn single_core_baseline_runs_and_conserves_count() {
        let cfg = MdConfig {
            n_particles: 200,
            steps: 3,
            grid: 4,
            box_l: 8.0,
            ..MdConfig::new(200)
        };
        let r = run_single_core_cpu(&cfg);
        assert_eq!(r.energies.len(), 3);
        assert!(r.energies.iter().all(|e| e.is_finite()));
        assert!(r.wall > 0.0);
    }
}
