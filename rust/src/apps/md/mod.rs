//! 2D molecular dynamics mini-app (paper section 4.2).
//!
//! Space is partitioned into patches owning their particles; compute
//! objects (patch-pair work requests) evaluate LJ cutoff forces; particles
//! migrate between patches after integration. MdInteract requests have
//! both CPU and GPU kernels, so this is the app that exercises dynamic
//! hybrid scheduling (Fig 5).

pub mod patch;
pub mod sim;

pub use patch::{MdParticle, Patch, PatchParams};
pub use sim::{
    job_spec, job_spec_named, run, run_single_core_cpu, MdConfig, MdResult,
    MD_COLLECTION,
};
