//! Figure-regeneration harness and micro-bench helpers.
//!
//! Every table/figure of the paper's evaluation section has a `run_figN`
//! function here that executes the relevant configurations and prints the
//! same series the paper plots, with the paper's claimed deltas alongside
//! ours. `cargo bench` binaries (rust/benches/) and the `gcharm figures`
//! CLI both call these. See DESIGN.md section 4 for the experiment index.
//!
//! Absolute numbers differ from the paper (CPU PJRT executor instead of a
//! Kepler K20): the reproduction targets are the *orderings and ratios*.
//! Modeled-K20 times (runtime::device_sim) are printed next to measured
//! wall clock.

use std::time::Instant;

use crate::apps::md::{self, MdConfig};
use crate::apps::nbody::{self, dataset::DatasetSpec, NbodyConfig};
use crate::coordinator::{
    CombinePolicy, Config, DataPolicy, ResidencyPolicy, SplitPolicy,
};

/// Plain-text table printer.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let s: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            println!("  {}", s.join("  "));
        };
        line(&self.headers);
        println!(
            "  {}",
            widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("  ")
        );
        for r in &self.rows {
            line(r);
        }
    }
}

/// Micro-benchmark: median ns/op over `reps` timed batches of `batch` calls.
pub fn bench_ns<F: FnMut()>(name: &str, batch: usize, reps: usize, mut f: F) -> f64 {
    // warmup
    for _ in 0..batch {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = samples[samples.len() / 2];
    println!("  {name:<44} {med:>12.1} ns/op  (n={batch}x{reps})");
    med
}

fn pct(better: f64, worse: f64) -> f64 {
    (worse - better) / worse * 100.0
}

/// Scale iteration counts / particle counts down for quick runs.
pub struct Scale {
    pub small_n: usize,
    pub large_n: usize,
    pub small_iters: usize,
    pub large_iters: usize,
}

impl Scale {
    pub fn quick() -> Scale {
        Scale { small_n: 4096, large_n: 12_288, small_iters: 2, large_iters: 2 }
    }

    pub fn full() -> Scale {
        Scale {
            small_n: 16 * 1024,
            large_n: 48 * 1024,
            small_iters: 6,
            large_iters: 3,
        }
    }
}

fn nbody_cfg(
    n: usize,
    iters: usize,
    base: &DatasetSpec,
    pes: usize,
    combine: CombinePolicy,
    data: DataPolicy,
) -> NbodyConfig {
    let mut cfg = NbodyConfig::new(DatasetSpec { n, ..base.clone() });
    cfg.iters = iters;
    cfg.runtime = Config {
        pes,
        combine,
        data_policy: data,
        ..Config::default()
    };
    cfg
}

/// Fig 2: dynamic vs static combining, small and large datasets.
/// Paper: dynamic is 8-38% faster (small), ~19% (large).
pub fn run_fig2(scale: &Scale) {
    println!("\n### Figure 2: dynamic vs static combining strategies (ChaNGa)");
    println!("paper claim: adaptive 8-38% faster on cube300, ~19% on lambs");
    let mut t = Table::new(
        "Fig 2",
        &[
            "dataset", "strategy", "wall(s)", "modeledK20(s)", "launches",
            "avg batch", "idle flushes",
        ],
    );
    for (label, base, n, iters) in [
        ("small(cube300~)", DatasetSpec::cube300(), scale.small_n, scale.small_iters),
        ("large(lambs~)", DatasetSpec::lambs(), scale.large_n, scale.large_iters),
    ] {
        let mut walls = Vec::new();
        for (name, combine) in [
            ("static(100)", CombinePolicy::StaticEvery(100)),
            ("adaptive", CombinePolicy::Adaptive),
        ] {
            let cfg = nbody_cfg(
                n,
                iters,
                &base,
                4,
                combine,
                DataPolicy::ReuseSorted,
            );
            let r = nbody::run(&cfg).expect("nbody run");
            walls.push(r.wall);
            t.row(vec![
                label.to_string(),
                name.to_string(),
                format!("{:.3}", r.wall),
                format!("{:.3}", r.report.modeled_total()),
                r.report.launches.to_string(),
                format!("{:.1}", r.report.avg_batch()),
                r.report.flush_idle.to_string(),
            ]);
        }
        let delta = pct(walls[1], walls[0]);
        println!(
            "  -> {label}: adaptive vs static = {delta:+.1}% reduction \
             (paper: 8-38% small / ~19% large)"
        );
    }
    t.print();
}

/// Fig 3: GPU kernel + transfer times for no-reuse / reuse / reuse+sort.
/// Paper: reuse cuts transfers 62% but inflates kernel 49%; sorting
/// recovers ~10% of kernel time; reuse+sort beats no-reuse by ~12% total.
pub fn run_fig3(scale: &Scale) {
    println!("\n### Figure 3: data reuse + coalescing (large dataset, 8 cores)");
    println!(
        "paper claim: reuse -62% transfer, +49% kernel; reuse+sort -12% total \
         vs no-reuse, kernel -10% vs reuse-only"
    );
    let mut t = Table::new(
        "Fig 3",
        &[
            "policy", "kernel wall(s)", "kernel K20(s)", "xfer K20(s)",
            "xfer MiB", "hit rate", "total K20(s)",
        ],
    );
    let base = DatasetSpec::lambs();
    let mut rows: Vec<(String, f64, f64, f64)> = Vec::new();
    // The residency ablation rider (ISSUE 7): the reuse rows run once
    // per eviction policy — plain LRU vs the reuse-graph lookahead with
    // ahead-of-flush prefetch. No-reuse never touches the tables, so one
    // row suffices there.
    let mut residency_rows: Vec<(String, u64, f64, u64, u64)> = Vec::new();
    for (name, policy, residency) in [
        ("no-reuse", DataPolicy::NoReuse, ResidencyPolicy::Lru),
        ("reuse (lru)", DataPolicy::Reuse, ResidencyPolicy::Lru),
        ("reuse (graph)", DataPolicy::Reuse, ResidencyPolicy::ReuseGraph),
        ("reuse+sort (lru)", DataPolicy::ReuseSorted, ResidencyPolicy::Lru),
        (
            "reuse+sort (graph)",
            DataPolicy::ReuseSorted,
            ResidencyPolicy::ReuseGraph,
        ),
    ] {
        let mut cfg = nbody_cfg(
            scale.large_n,
            scale.large_iters,
            &base,
            8,
            CombinePolicy::Adaptive,
            policy,
        );
        cfg.runtime.residency = residency;
        // Fig 3 isolates the force kernel (the reuse strategy's target);
        // Ewald launches are always contiguous and would dilute the series.
        cfg.do_ewald = false;
        let r = nbody::run(&cfg).expect("nbody run");
        let rep = &r.report;
        // the paper's three-way comparison keys off the graph rows (the
        // runtime default); no-reuse is policy-free
        if !name.ends_with("(lru)") {
            rows.push((
                name.to_string(),
                rep.kernel_wall,
                rep.kernel_modeled,
                rep.transfer_modeled,
            ));
        }
        if policy != DataPolicy::NoReuse {
            residency_rows.push((
                name.to_string(),
                rep.transfer_bytes,
                rep.hit_rate(),
                rep.prefetch_hits,
                rep.prefetch_wasted,
            ));
        }
        t.row(vec![
            name.to_string(),
            format!("{:.3}", rep.kernel_wall),
            format!("{:.3}", rep.kernel_modeled),
            format!("{:.3}", rep.transfer_modeled),
            format!("{:.1}", rep.transfer_bytes as f64 / (1 << 20) as f64),
            format!("{:.0}%", rep.hit_rate() * 100.0),
            format!("{:.3}", rep.modeled_total()),
        ]);
    }
    t.print();
    // lru -> graph deltas per data policy (pairs pushed in order)
    for pair in residency_rows.chunks(2) {
        if let [(name_l, x_l, h_l, _, _), (name_g, x_g, h_g, pf, pw)] = pair {
            println!(
                "  -> residency {name_l} -> {name_g}: transfer {:+.1}%, hit \
                 rate {:.0}% -> {:.0}% (prefetch {pf} hits / {pw} wasted)",
                (*x_g as f64 - *x_l as f64) / (*x_l as f64).max(1.0) * 100.0,
                h_l * 100.0,
                h_g * 100.0,
            );
        }
    }
    let (k0, x0) = (rows[0].2, rows[0].3);
    let (k1, x1) = (rows[1].2, rows[1].3);
    let (k2, _) = (rows[2].2, rows[2].3);
    println!(
        "  -> transfer: reuse vs no-reuse = {:+.0}% (paper -62%)",
        -pct(x1, x0)
    );
    println!(
        "  -> kernel (modeled): reuse vs no-reuse = {:+.0}% (paper +49%)",
        (k1 - k0) / k0 * 100.0
    );
    println!(
        "  -> kernel (modeled): reuse+sort vs reuse = {:+.0}% (paper ~-10%)",
        (k2 - k1) / k1 * 100.0
    );
    println!(
        "  -> total (modeled): reuse+sort vs no-reuse = {:+.0}% (paper ~-12%)",
        (rows[2].2 + rows[2].3 - k0 - x0) / (k0 + x0) * 100.0
    );
}

/// Fig 4: adaptive vs static vs hand-tuned vs CPU-only across core counts.
pub fn run_fig4(scale: &Scale) {
    println!("\n### Figure 4: comparison with static strategies and hand-tuned code");
    println!(
        "paper claim: adaptive < static; hand-tuned fastest; similar scaling"
    );
    let mut t = Table::new(
        "Fig 4 (wall seconds, large dataset)",
        &["pes", "cpu-only", "gcharm-static", "gcharm-adaptive", "hand-tuned"],
    );
    let base = DatasetSpec::lambs();
    for pes in [1usize, 2, 4, 8] {
        let cpu = nbody::run_cpu_only(&nbody_cfg(
            scale.large_n,
            scale.large_iters,
            &base,
            pes,
            CombinePolicy::Adaptive,
            DataPolicy::NoReuse,
        ))
        .expect("cpu run");
        let stat = nbody::run(&nbody_cfg(
            scale.large_n,
            scale.large_iters,
            &base,
            pes,
            CombinePolicy::StaticEvery(100),
            DataPolicy::Reuse,
        ))
        .expect("static run");
        let adapt = nbody::run(&nbody_cfg(
            scale.large_n,
            scale.large_iters,
            &base,
            pes,
            CombinePolicy::Adaptive,
            DataPolicy::ReuseSorted,
        ))
        .expect("adaptive run");
        let hand = nbody::handtuned::run_handtuned(&nbody_cfg(
            scale.large_n,
            scale.large_iters,
            &base,
            pes,
            CombinePolicy::Adaptive,
            DataPolicy::NoReuse,
        ))
        .expect("handtuned run");
        t.row(vec![
            pes.to_string(),
            format!("{:.3}", cpu.wall),
            format!("{:.3}", stat.wall),
            format!("{:.3}", adapt.wall),
            format!("{:.3}", hand.wall),
        ]);
        if pes == 8 {
            println!(
                "  -> 8 pes: adaptive vs static {:+.1}%; adaptive vs cpu-only \
                 {:+.1}% (paper: ~62% over CPU for lambs)",
                pct(adapt.wall, stat.wall),
                pct(adapt.wall, cpu.wall),
            );
        }
    }
    t.print();
}

/// Fig 5: MD total times, static vs adaptive hybrid scheduling.
/// Paper: adaptive 10-15% faster; ~22% over single-core CPU.
pub fn run_fig5(scale: &Scale) {
    println!("\n### Figure 5: MD simulations, dynamic scheduling");
    println!("paper claim: adaptive split 10-15% faster than static; ~22% over 1-core CPU");
    let mut t = Table::new(
        "Fig 5 (wall seconds)",
        &[
            "particles", "1-core cpu", "static split", "adaptive split",
            "cpu/gpu items (adaptive)",
        ],
    );
    let sizes: Vec<usize> = if scale.large_n <= 16_384 {
        vec![2_048, 4_096, 8_192]
    } else {
        vec![4_096, 8_192, 16_384, 32_768]
    };
    // The hybrid CPU half runs on the coordinator's worker pool
    // (coordinator::cpu_pool), chunked by data_items; size it like the
    // PE count so the split ratio is comparable across rows.
    let cpu_workers = 4;
    println!("hybrid CPU pool: {cpu_workers} workers (chunked by data items)");
    for n in sizes {
        let mk = |split: SplitPolicy| {
            let mut cfg = MdConfig::new(n); // box/grid auto-scale with n
            cfg.steps = scale.small_iters.max(2) * 3;
            cfg.runtime = Config {
                pes: 4,
                split,
                hybrid: true,
                cpu_workers,
                ..Config::default()
            };
            cfg
        };
        let sc_cfg = mk(SplitPolicy::AdaptiveItems);
        let sc = md::run_single_core_cpu(&sc_cfg);
        let stat = md::run(&mk(SplitPolicy::StaticCount)).expect("static md");
        let adapt =
            md::run(&mk(SplitPolicy::AdaptiveItems)).expect("adaptive md");
        t.row(vec![
            n.to_string(),
            format!("{:.3}", sc.wall),
            format!("{:.3}", stat.wall),
            format!("{:.3}", adapt.wall),
            format!(
                "{}/{}",
                adapt.report.cpu_items, adapt.report.gpu_items
            ),
        ]);
        println!(
            "  -> n={n}: adaptive vs static {:+.1}% (paper 10-15%); vs 1-core \
             {:+.1}% (paper ~22%)",
            pct(adapt.wall, stat.wall),
            pct(adapt.wall, sc.wall),
        );
    }
    t.print();
}

/// Ablations over the adaptive combiner's two design parameters
/// (DESIGN.md section 4): the occupancy-derived maxSize (what if we combined
/// fewer/more than the occupancy calculator says?) and the idle-flush
/// threshold multiplier (the paper's 2 x maxInterval).
pub fn run_ablation(scale: &Scale) {
    println!("\n### Ablation: combiner design choices (small dataset)");
    let base = DatasetSpec::cube300();

    let mut t = Table::new(
        "maxSize ablation (static flush target via StaticEvery)",
        &["combine target", "wall(s)", "launches", "avg batch"],
    );
    for period in [13usize, 26, 52, 104, 208] {
        let cfg = nbody_cfg(
            scale.small_n,
            scale.small_iters,
            &base,
            4,
            CombinePolicy::StaticEvery(period),
            DataPolicy::ReuseSorted,
        );
        let r = nbody::run(&cfg).expect("nbody run");
        t.row(vec![
            period.to_string(),
            format!("{:.3}", r.wall),
            r.report.launches.to_string(),
            format!("{:.1}", r.report.avg_batch()),
        ]);
    }
    t.print();
    println!(
        "  (on a real GPU the occupancy-derived 104 sits at the minimum: \
         smaller targets under-fill the SMs, larger ones add batching \
         latency. On the CPU PJRT executor launch cost scales with batch \
         compute, so the left side of the curve flattens -- the sweep \
         documents the tradeoff the occupancy model resolves.)"
    );
}

/// Section 4.3's occupancy table (validates the combiner's maxSize inputs).
pub fn print_occupancy_table() {
    use crate::runtime::{occupancy, GpuSpec, KernelResources};
    let spec = GpuSpec::kepler_k20();
    let mut t = Table::new(
        "Occupancy model (paper section 4.3)",
        &["kernel", "occupancy", "blocks/SM", "maxSize", "paper maxSize"],
    );
    for (name, k, paper) in [
        ("force", KernelResources::force_kernel(), "104"),
        ("ewald", KernelResources::ewald_kernel(), "65"),
        ("md", KernelResources::md_kernel(), "-"),
    ] {
        let o = occupancy(&spec, &k);
        t.row(vec![
            name.to_string(),
            format!("{:.1}%", o.occupancy * 100.0),
            o.blocks_per_sm.to_string(),
            o.max_size.to_string(),
            paper.to_string(),
        ]);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new("t", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print();
    }

    #[test]
    fn pct_math() {
        assert!((pct(80.0, 100.0) - 20.0).abs() < 1e-12);
    }

    #[test]
    fn bench_ns_returns_positive() {
        let mut x = 0u64;
        let ns = bench_ns("noop", 100, 3, || x = x.wrapping_add(1));
        assert!(ns >= 0.0);
        assert!(x > 0);
    }
}
