//! # G-Charm-RS
//!
//! Reproduction of *Strategies for Efficient Executions of Irregular
//! Message-Driven Parallel Applications on GPU Systems* (Rengasamy &
//! Vadhiyar, 2020) as a three-layer rust + JAX + Pallas stack:
//!
//! - **Layer 3** (`coordinator`): the G-Charm runtime -- message-driven
//!   chares over PE worker threads, adaptive kernel combining, data reuse
//!   with sorted-index coalescing, and dynamic CPU/GPU hybrid scheduling.
//! - **Layer 2/1** (`python/compile`): JAX graphs calling Pallas kernels,
//!   AOT-lowered to HLO text once at build time (`make artifacts`).
//! - **Runtime bridge** (`runtime`): the simulated GPU device -- a native
//!   sim backend by default, or the PJRT CPU client executing the AOT
//!   artifacts with `--features pjrt` -- plus the analytic Kepler K20
//!   occupancy/cost model. The launch hot path stages through a
//!   zero-allocation arena and pipelines staging against execution
//!   (`runtime::staging`, PERF.md).
//!
//! The kernel surface is **open**: apps register kernel families at
//! startup (`coordinator::GCharm::register_kernel` with a
//! `KernelDescriptor`) and submit shape-checked `Tile` payloads tagged
//! with the returned `KernelKindId`; every scheduling layer is
//! table-driven off the registry. See PERF.md, "Adding a workload".
//!
//! Applications (`apps`): a ChaNGa-style Barnes-Hut N-Body simulation, a
//! 2D molecular dynamics mini-app -- the paper's two evaluation
//! workloads -- and an SpMV-style sparse neighbor-update app registered
//! purely through the public API. See DESIGN.md for the experiment index.
pub mod apps;
pub mod bench;
pub mod coordinator;
pub mod runtime;
pub mod util;
