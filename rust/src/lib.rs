//! # G-Charm-RS
//!
//! Reproduction of *Strategies for Efficient Executions of Irregular
//! Message-Driven Parallel Applications on GPU Systems* (Rengasamy &
//! Vadhiyar, 2020), grown into a **persistent, multi-tenant runtime**: a
//! three-layer rust + JAX + Pallas stack that serves concurrent jobs on
//! one long-lived device pool.
//!
//! - **Layer 3** (`coordinator`): the G-Charm runtime -- message-driven
//!   chares over PE worker threads, adaptive kernel combining, data reuse
//!   with sorted-index coalescing, and dynamic CPU/GPU hybrid scheduling.
//! - **Layer 2/1** (`python/compile`): JAX graphs calling Pallas kernels,
//!   AOT-lowered to HLO text once at build time (`make artifacts`).
//! - **Runtime bridge** (`runtime`): the simulated GPU device -- a native
//!   sim backend by default, or the PJRT CPU client executing the AOT
//!   artifacts with `--features pjrt` -- plus the analytic Kepler K20
//!   occupancy/cost model. The launch hot path stages through a
//!   zero-allocation arena and pipelines staging against execution
//!   (`runtime::staging`, PERF.md).
//!
//! ## Jobs, not runs
//!
//! The primary entry point is [`coordinator::Runtime`]: it owns the
//! sharded device pool, the **append-only shared kernel registry**, the
//! hybrid scheduler, and the PE worker threads for its whole lifetime.
//! Applications call [`coordinator::Runtime::submit_job`] with a
//! [`coordinator::JobSpec`] -- the chare set, the kernel-family
//! registrations, and a *driver* closure whose return is the job's
//! completion condition -- and receive a [`coordinator::JobHandle`] with
//! blocking `wait() -> JobReport`, non-blocking `poll()`, `cancel()`, and
//! a live `metrics_snapshot()`.
//!
//! Tenancy is real, not time-sliced: identical kernel registrations from
//! different jobs resolve to one shared kind id, so the combiners may
//! merge tiles from **different jobs into one launch** (cross-job
//! combining -- the paper's adaptive combining extended across tenants),
//! with per-job accounting split back out exactly on completion
//! ([`coordinator::JobReport`] counters sum to the
//! [`coordinator::PoolReport`] totals) and a weighted-fair share learned
//! per `(job, kind)` keeping one heavy job from starving its co-tenants.
//! Reductions, quiescence, residency keys, and routing affinity are all
//! namespaced by [`coordinator::JobId`]. `gcharm serve` runs a mixed
//! nbody + md + 2x spmv trace concurrently on one runtime.
//!
//! The pre-redesign one-shot API survives as [`coordinator::GCharm`]:
//! one interactively driven job on a private runtime (`new -> register
//! kernels/chares -> start -> drive -> shutdown`), so existing examples
//! and baselines keep working unchanged.
//!
//! The kernel surface is **open**: jobs register kernel families
//! (`KernelDescriptor` in their specs, or
//! `GCharm::register_kernel`) and submit shape-checked `Tile` payloads
//! tagged with the returned `KernelKindId`; every scheduling layer is
//! table-driven off the registry, and a live runtime learns new families
//! as jobs bring them. See PERF.md, "Adding a workload" and "Serving
//! mixed workloads".
//!
//! Applications (`apps`): a ChaNGa-style Barnes-Hut N-Body simulation, a
//! 2D molecular dynamics mini-app -- the paper's two evaluation
//! workloads -- and an SpMV-style sparse neighbor-update app registered
//! purely through the public API. Each exposes both a one-shot `run` and
//! a `job_spec` builder for mixed-workload serving. See DESIGN.md for
//! the experiment index.
pub mod apps;
pub mod bench;
// Deterministic fault-injection harness (`cargo test --features chaos`,
// `gcharm chaos --seed N`). Feature-gated with the coordinator's
// injection hooks so the release hot path carries none of it; also
// compiled under `cfg(test)` so the schedule/invariant unit tests run in
// the plain tier-1 suite.
#[cfg(any(test, feature = "chaos"))]
pub mod chaos;
pub mod coordinator;
// Multi-node transport (ISSUE 9): `Transport` trait with deterministic
// in-process `Loopback` and length-prefixed `Tcp` meshes, the cluster
// session driving cross-node reductions, remote chare messages, and
// watermark-gated batch steals. `Cluster::loopback(1, ...)` reproduces
// the single-process `Runtime` bitwise.
pub mod net;
pub mod runtime;
// Serving front end (ISSUE 10): bounded admission with
// Block/Reject/Shed backpressure, per-tenant QoS classes layered onto
// the weighted-fair combine quotas, deadline-aware combiner flushing
// for latency-class jobs, class-ordered load shedding with an exactly
// closing admission ledger, and a scrapeable plaintext metrics
// endpoint over the net-layer framing.
pub mod serve;
pub mod util;
