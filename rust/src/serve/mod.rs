//! Serving front end (ISSUE 10): admission control, QoS classes,
//! deadline-aware flushing, and load shedding above the multi-tenant
//! [`Runtime`].
//!
//! The paper's adaptive strategies minimize device idling for
//! *closed-loop* benchmark drivers; a serving tier faces open-loop,
//! bursty, heavy-tailed arrivals, where the figure of merit shifts from
//! makespan to tail latency under load (ROADMAP item 3; Atos makes the
//! same queue-driven-admission argument at kernel granularity). This
//! module is that layer:
//!
//! * [`ServeFront`] — a bounded admission gate over
//!   [`Runtime::submit_job`]: per-class depth limits plus a pool-wide
//!   cap, with explicit backpressure per [`AdmissionPolicy`] (`Block`
//!   waits, `Reject` refuses, `Shed` preempts the lowest class first
//!   and refuses only when nothing lower exists).
//! * [`QosClass`] — per-tenant classes layered onto the coordinator's
//!   weighted-fair combine quotas: a latency-sensitive job gets an
//!   enlarged share of oversubscribed flushes, a deadline budget that
//!   arms the combiners' `FlushReason::Deadline` trigger, and immunity
//!   from cross-node steal; best-effort gets a reduced share and sheds
//!   first.
//! * [`ServeStats`] — the per-class admission ledger. The pool-level
//!   copy in `PoolReport` must close exactly
//!   (`offered == admitted + rejected + shed`), audited by
//!   `chaos::invariants` with falsifiability tests.
//! * [`MetricsEndpoint`] — a scrapeable plaintext endpoint serving the
//!   live pool snapshot, per-job counters, and the serve ledger over
//!   the net layer's length-prefixed framing.

mod endpoint;

pub use endpoint::MetricsEndpoint;

use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::{
    JobHandle, JobSpec, JobState, JobStatus, Runtime,
};

/// Per-tenant quality-of-service class. Classes map onto the
/// coordinator's existing weighted-fair machinery (see
/// [`QosClass::weight_multiplier`]) rather than a separate scheduler:
/// one mechanism, three operating points.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QosClass {
    /// Interactive traffic: enlarged combine quota, a deadline budget
    /// that flushes combiners early (`FlushReason::Deadline`), never
    /// shipped over the wire by cross-node steal, shed last.
    LatencySensitive,
    /// Batch traffic: the neutral baseline (multiplier 1.0, no
    /// deadline, steal-eligible).
    Throughput,
    /// Scavenger traffic: reduced combine quota, shed first when the
    /// pool saturates.
    BestEffort,
}

impl QosClass {
    /// Every class, in [`QosClass::index`] order (the per-class array
    /// layout of [`ServeStats`] and `ServeConfig::class_depth`).
    pub const ALL: [QosClass; 3] =
        [QosClass::LatencySensitive, QosClass::Throughput, QosClass::BestEffort];

    /// Dense index into per-class arrays.
    pub fn index(self) -> usize {
        match self {
            QosClass::LatencySensitive => 0,
            QosClass::Throughput => 1,
            QosClass::BestEffort => 2,
        }
    }

    /// Shed order: lower ranks shed first. A saturated pool preempts
    /// strictly-lower-rank tenants only, so best-effort never evicts
    /// best-effort and nothing ever evicts latency traffic.
    pub fn shed_rank(self) -> u8 {
        match self {
            QosClass::BestEffort => 0,
            QosClass::Throughput => 1,
            QosClass::LatencySensitive => 2,
        }
    }

    /// Multiplier composed onto the learned per-(job, kind) fair-share
    /// weight in the combiners: latency-class jobs hold 4x their
    /// learned share of oversubscribed flushes, best-effort a quarter.
    pub fn weight_multiplier(self) -> f64 {
        match self {
            QosClass::LatencySensitive => 4.0,
            QosClass::Throughput => 1.0,
            QosClass::BestEffort => 0.25,
        }
    }

    /// Stable name (CLI flags, metrics labels).
    pub fn name(self) -> &'static str {
        match self {
            QosClass::LatencySensitive => "latency",
            QosClass::Throughput => "throughput",
            QosClass::BestEffort => "best-effort",
        }
    }

    /// Parse a [`QosClass::name`] (CLI `--qos`).
    pub fn parse(s: &str) -> Option<QosClass> {
        match s {
            "latency" | "latency-sensitive" => {
                Some(QosClass::LatencySensitive)
            }
            "throughput" => Some(QosClass::Throughput),
            "best-effort" | "besteffort" => Some(QosClass::BestEffort),
            _ => None,
        }
    }
}

/// What a full queue does to the next offered job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Wait (bounded only by the caller) until depth frees up:
    /// backpressure propagates to the producer.
    Block,
    /// Refuse immediately: the producer sees the rejection and decides.
    Reject,
    /// Preempt the oldest strictly-lower-class active job to make room;
    /// refuse the offer itself only when nothing lower is running.
    Shed,
}

impl AdmissionPolicy {
    /// Stable name (CLI flags, metrics labels).
    pub fn name(self) -> &'static str {
        match self {
            AdmissionPolicy::Block => "block",
            AdmissionPolicy::Reject => "reject",
            AdmissionPolicy::Shed => "shed",
        }
    }

    /// Parse an [`AdmissionPolicy::name`] (CLI `--admission`).
    pub fn parse(s: &str) -> Option<AdmissionPolicy> {
        match s {
            "block" => Some(AdmissionPolicy::Block),
            "reject" => Some(AdmissionPolicy::Reject),
            "shed" => Some(AdmissionPolicy::Shed),
            _ => None,
        }
    }
}

/// Front-end limits and policy.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// What happens when the offered job's class (or the pool) is full.
    pub policy: AdmissionPolicy,
    /// Active-job limit per class, indexed by [`QosClass::index`].
    pub class_depth: [usize; 3],
    /// Active-job limit across all classes.
    pub pool_depth: usize,
    /// Deadline budget (timeline seconds) handed to latency-sensitive
    /// admissions; arms the coordinator's deadline-aware flush.
    pub deadline: Option<f64>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            policy: AdmissionPolicy::Block,
            class_depth: [4, 4, 4],
            pool_depth: 8,
            deadline: Some(0.05),
        }
    }
}

impl ServeConfig {
    /// Reject configurations that can never admit anything.
    pub fn validate(&self) -> Result<()> {
        if self.pool_depth == 0 {
            bail!("serve: pool_depth must be at least 1");
        }
        for c in QosClass::ALL {
            if self.class_depth[c.index()] == 0 {
                bail!("serve: class_depth[{}] must be at least 1", c.name());
            }
        }
        if let Some(d) = self.deadline {
            if !d.is_finite() || d <= 0.0 {
                bail!("serve: deadline must be positive and finite");
            }
        }
        Ok(())
    }
}

/// Per-class admission counters of one [`ServeFront`]. Arrays are
/// indexed by [`QosClass::index`]. The front-end-local ledger
/// `offered == admitted + rejected + shed` closes whenever no `offer`
/// is mid-flight; the pool-level copy in `PoolReport` (fed one decision
/// at a time through `Runtime::serve_account`) closes always.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Jobs offered to the front end.
    pub offered: [u64; 3],
    /// Offers admitted to the runtime.
    pub admitted: [u64; 3],
    /// Offers refused (policy `Reject`, or a failed registration).
    pub rejected: [u64; 3],
    /// Offers shed at the door (policy `Shed`, nothing lower running).
    pub shed: [u64; 3],
    /// *Admitted* jobs later preempted to make room for a higher class.
    /// Not part of the offer ledger — a preempted job was admitted and
    /// seals as `Cancelled`.
    pub preempted: [u64; 3],
    /// Admitted jobs observed sealed by `reap`.
    pub completed: [u64; 3],
}

impl ServeStats {
    /// Offers across all classes.
    pub fn offered_total(&self) -> u64 {
        self.offered.iter().sum()
    }

    /// Admissions across all classes.
    pub fn admitted_total(&self) -> u64 {
        self.admitted.iter().sum()
    }

    /// Rejections across all classes.
    pub fn rejected_total(&self) -> u64 {
        self.rejected.iter().sum()
    }

    /// Door-sheds across all classes.
    pub fn shed_total(&self) -> u64 {
        self.shed.iter().sum()
    }

    /// The admission ledger: every offer got exactly one verdict.
    pub fn ledger_closes(&self) -> bool {
        self.offered_total()
            == self.admitted_total() + self.rejected_total() + self.shed_total()
    }
}

impl std::fmt::Display for ServeStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for c in QosClass::ALL {
            let i = c.index();
            writeln!(
                f,
                "{:<12} offered {} / admitted {} / rejected {} / shed {} / preempted {} / completed {}",
                c.name(),
                self.offered[i],
                self.admitted[i],
                self.rejected[i],
                self.shed[i],
                self.preempted[i],
                self.completed[i]
            )?;
        }
        write!(
            f,
            "total        offered {} = admitted {} + rejected {} + shed {}",
            self.offered_total(),
            self.admitted_total(),
            self.rejected_total(),
            self.shed_total()
        )
    }
}

/// The verdict of one [`ServeFront::offer`].
pub enum Admission {
    /// Submitted to the runtime; the handle is the caller's to wait on.
    Admitted(JobHandle),
    /// Refused under [`AdmissionPolicy::Reject`].
    Rejected,
    /// Shed at the door under [`AdmissionPolicy::Shed`] (the offered
    /// class had no strictly-lower active job to preempt).
    Shed,
}

/// One admitted job the front end is tracking.
struct Active {
    class: QosClass,
    state: Arc<JobState>,
}

/// The admission gate. Thread-safe: producers may `offer` from several
/// threads against one shared front end.
pub struct ServeFront {
    cfg: ServeConfig,
    stats: Arc<Mutex<ServeStats>>,
    active: Mutex<Vec<Active>>,
}

/// Poll interval of a blocked `offer` and of `drain`.
const BLOCK_POLL: Duration = Duration::from_micros(100);

impl ServeFront {
    /// Build a front end over a validated configuration.
    pub fn new(cfg: ServeConfig) -> Result<ServeFront> {
        cfg.validate()?;
        Ok(ServeFront {
            cfg,
            stats: Arc::new(Mutex::new(ServeStats::default())),
            active: Mutex::new(Vec::new()),
        })
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Point-in-time copy of the front end's counters.
    pub fn stats(&self) -> ServeStats {
        self.stats.lock().unwrap().clone()
    }

    /// The shared counters, for a [`MetricsEndpoint`].
    pub fn stats_arc(&self) -> Arc<Mutex<ServeStats>> {
        self.stats.clone()
    }

    /// Jobs currently admitted and not yet observed sealed.
    pub fn active_len(&self) -> usize {
        self.reap();
        self.active.lock().unwrap().len()
    }

    /// Offer one job at `class`. Exactly one of the [`Admission`]
    /// verdicts comes back (or an error, counted as a rejection):
    /// admission depth is `min(class_depth[class], pool_depth)` over
    /// the jobs still running. `Block` waits for room; `Reject` refuses
    /// a full queue; `Shed` preempts the oldest strictly-lower-class
    /// active job when the pool (not the class) is what's full, and
    /// sheds the offer itself otherwise.
    pub fn offer(
        &self,
        rt: &Runtime,
        class: QosClass,
        spec: JobSpec,
    ) -> Result<Admission> {
        self.stats.lock().unwrap().offered[class.index()] += 1;
        loop {
            self.reap();
            let mut active = self.active.lock().unwrap();
            let class_n =
                active.iter().filter(|a| a.class == class).count();
            let has_room = class_n < self.cfg.class_depth[class.index()]
                && active.len() < self.cfg.pool_depth;
            if has_room {
                drop(active);
                return self.admit(rt, class, spec);
            }
            match self.cfg.policy {
                AdmissionPolicy::Block => {
                    drop(active);
                    std::thread::sleep(BLOCK_POLL);
                }
                AdmissionPolicy::Reject => {
                    drop(active);
                    self.stats.lock().unwrap().rejected[class.index()] += 1;
                    rt.serve_account(1, 0, 1, 0)?;
                    return Ok(Admission::Rejected);
                }
                AdmissionPolicy::Shed => {
                    // Preemption only helps when the offered class has
                    // its own headroom; a class at its depth limit is
                    // being throttled, not crowded out.
                    let victim = (class_n
                        < self.cfg.class_depth[class.index()])
                    .then(|| Self::victim_index(&active, class))
                    .flatten();
                    if let Some(i) = victim {
                        let v = active.remove(i);
                        v.state.cancel();
                        drop(active);
                        self.stats.lock().unwrap().preempted
                            [v.class.index()] += 1;
                        return self.admit(rt, class, spec);
                    }
                    drop(active);
                    self.stats.lock().unwrap().shed[class.index()] += 1;
                    rt.serve_account(1, 0, 0, 1)?;
                    return Ok(Admission::Shed);
                }
            }
        }
    }

    /// The oldest active job of the lowest shed rank strictly below the
    /// incoming class, if any.
    fn victim_index(active: &[Active], incoming: QosClass) -> Option<usize> {
        let mut best: Option<(usize, u8)> = None;
        for (i, a) in active.iter().enumerate() {
            let r = a.class.shed_rank();
            if r < incoming.shed_rank()
                && best.is_none_or(|(_, br)| r < br)
            {
                best = Some((i, r));
            }
        }
        best.map(|(i, _)| i)
    }

    fn admit(
        &self,
        rt: &Runtime,
        class: QosClass,
        spec: JobSpec,
    ) -> Result<Admission> {
        let handle = match rt.submit_job(spec) {
            Ok(h) => h,
            Err(e) => {
                // A failed registration is a rejection: the ledger must
                // still close around the error path.
                self.stats.lock().unwrap().rejected[class.index()] += 1;
                rt.serve_account(1, 0, 1, 0)?;
                return Err(e);
            }
        };
        let deadline = match class {
            QosClass::LatencySensitive => self.cfg.deadline,
            _ => None,
        };
        rt.set_job_qos(handle.job(), class, deadline)?;
        rt.serve_account(1, 1, 0, 0)?;
        self.stats.lock().unwrap().admitted[class.index()] += 1;
        self.active
            .lock()
            .unwrap()
            .push(Active { class, state: handle.state_arc() });
        Ok(Admission::Admitted(handle))
    }

    /// Drop sealed jobs from the active set, counting them completed.
    pub fn reap(&self) {
        let mut active = self.active.lock().unwrap();
        let mut stats = self.stats.lock().unwrap();
        active.retain(|a| {
            if a.state.status() == JobStatus::Running {
                true
            } else {
                stats.completed[a.class.index()] += 1;
                false
            }
        });
    }

    /// Wait until every admitted job has sealed (poll + reap). The
    /// runtime's own `shutdown` waits on preempted jobs' drains.
    pub fn drain(&self) {
        loop {
            self.reap();
            if self.active.lock().unwrap().is_empty() {
                return;
            }
            std::thread::sleep(BLOCK_POLL);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_parse_round_trips_names() {
        for c in QosClass::ALL {
            assert_eq!(QosClass::parse(c.name()), Some(c));
        }
        assert_eq!(
            QosClass::parse("latency-sensitive"),
            Some(QosClass::LatencySensitive)
        );
        assert!(QosClass::parse("platinum").is_none());
        for p in
            [AdmissionPolicy::Block, AdmissionPolicy::Reject, AdmissionPolicy::Shed]
        {
            assert_eq!(AdmissionPolicy::parse(p.name()), Some(p));
        }
        assert!(AdmissionPolicy::parse("panic").is_none());
    }

    #[test]
    fn class_indices_are_dense_and_ranked() {
        let mut seen = [false; 3];
        for c in QosClass::ALL {
            seen[c.index()] = true;
        }
        assert_eq!(seen, [true; 3]);
        assert!(
            QosClass::BestEffort.shed_rank()
                < QosClass::Throughput.shed_rank()
        );
        assert!(
            QosClass::Throughput.shed_rank()
                < QosClass::LatencySensitive.shed_rank()
        );
        assert!(
            QosClass::LatencySensitive.weight_multiplier()
                > QosClass::Throughput.weight_multiplier()
        );
        assert!(
            QosClass::BestEffort.weight_multiplier()
                < QosClass::Throughput.weight_multiplier()
        );
    }

    #[test]
    fn config_validation_rejects_degenerate_limits() {
        assert!(ServeConfig::default().validate().is_ok());
        let zero_pool = ServeConfig { pool_depth: 0, ..Default::default() };
        assert!(zero_pool.validate().is_err());
        let zero_class =
            ServeConfig { class_depth: [1, 0, 1], ..Default::default() };
        assert!(zero_class.validate().is_err());
        let bad_deadline =
            ServeConfig { deadline: Some(0.0), ..Default::default() };
        assert!(bad_deadline.validate().is_err());
        let nan_deadline =
            ServeConfig { deadline: Some(f64::NAN), ..Default::default() };
        assert!(nan_deadline.validate().is_err());
    }

    #[test]
    fn stats_ledger_closes_by_construction() {
        let mut s = ServeStats::default();
        assert!(s.ledger_closes());
        s.offered[0] = 5;
        s.admitted[0] = 3;
        s.rejected[1] = 1;
        s.shed[2] = 1;
        assert!(s.ledger_closes());
        s.shed[2] = 2;
        assert!(!s.ledger_closes());
        let text = format!("{s}");
        assert!(text.contains("latency"), "{text}");
        assert!(text.contains("offered 5 = admitted 3"), "{text}");
    }
}
