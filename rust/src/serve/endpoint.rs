//! Scrapeable plaintext metrics endpoint for the serving front end.
//!
//! One background thread accepts TCP connections and answers each with
//! a single length-prefixed text frame (the net layer's
//! [`write_text_frame`] framing — scrapers share one wire format with
//! the cluster transport) containing prometheus-style `name{labels}
//! value` lines: the serve admission ledger per class, the live
//! pool-wide counters from a [`PoolSnapshotHandle`], and per-live-job
//! gauges from the runtime's shared job table. Rendering happens per
//! scrape, so every connection sees current values.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{PoolSnapshotHandle, Shared};
use crate::net::{read_text_frame, write_text_frame};

use super::{QosClass, ServeStats};

/// The endpoint: bound at [`MetricsEndpoint::spawn`], scrapeable until
/// dropped (drop stops the accept thread and joins it).
pub struct MetricsEndpoint {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

/// Accept-loop poll interval while idle.
const ACCEPT_POLL: Duration = Duration::from_millis(10);

impl MetricsEndpoint {
    /// Bind `addr` (port 0 picks a free port — read the result from
    /// [`MetricsEndpoint::addr`]) and start answering scrapes with the
    /// live serve + pool + per-job counters.
    pub fn spawn(
        addr: &str,
        shared: Arc<Shared>,
        pool: PoolSnapshotHandle,
        stats: Arc<Mutex<ServeStats>>,
    ) -> Result<MetricsEndpoint> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("metrics endpoint: bind {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let handle = std::thread::Builder::new()
            .name("serve-metrics".into())
            .spawn(move || {
                while !flag.load(Ordering::SeqCst) {
                    match listener.accept() {
                        Ok((mut conn, _)) => {
                            let body = render(&shared, &pool, &stats);
                            let _ = conn.set_nodelay(true);
                            let _ = write_text_frame(&mut conn, &body);
                        }
                        Err(e)
                            if e.kind()
                                == std::io::ErrorKind::WouldBlock =>
                        {
                            std::thread::sleep(ACCEPT_POLL);
                        }
                        Err(_) => break,
                    }
                }
            })
            .context("spawn metrics endpoint")?;
        Ok(MetricsEndpoint { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// One scrape over a fresh connection: connect, read the single
    /// text frame, return its body. Used by tests and the CLI's
    /// self-scrape.
    pub fn scrape(addr: &SocketAddr) -> Result<String> {
        let mut conn = TcpStream::connect(addr)
            .with_context(|| format!("metrics scrape: connect {addr}"))?;
        conn.set_read_timeout(Some(Duration::from_secs(30)))?;
        Ok(read_text_frame(&mut conn)?)
    }
}

impl Drop for MetricsEndpoint {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Render one scrape body. Infallible: a pool snapshot that errors
/// (runtime shut down mid-scrape) just omits the pool section.
fn render(
    shared: &Shared,
    pool: &PoolSnapshotHandle,
    stats: &Mutex<ServeStats>,
) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let s = stats.lock().unwrap().clone();
    for c in QosClass::ALL {
        let i = c.index();
        let n = c.name();
        let _ = writeln!(out, "gcharm_serve_offered{{class=\"{n}\"}} {}", s.offered[i]);
        let _ = writeln!(out, "gcharm_serve_admitted{{class=\"{n}\"}} {}", s.admitted[i]);
        let _ = writeln!(out, "gcharm_serve_rejected{{class=\"{n}\"}} {}", s.rejected[i]);
        let _ = writeln!(out, "gcharm_serve_shed{{class=\"{n}\"}} {}", s.shed[i]);
        let _ = writeln!(out, "gcharm_serve_preempted{{class=\"{n}\"}} {}", s.preempted[i]);
        let _ = writeln!(out, "gcharm_serve_completed{{class=\"{n}\"}} {}", s.completed[i]);
    }
    if let Ok(r) = pool.pool_snapshot() {
        let _ = writeln!(out, "gcharm_pool_launches {}", r.launches);
        let _ = writeln!(out, "gcharm_pool_cross_job_launches {}", r.cross_job_launches);
        let _ = writeln!(out, "gcharm_pool_gpu_requests {}", r.gpu_requests);
        let _ = writeln!(out, "gcharm_pool_cpu_requests {}", r.cpu_requests);
        let _ = writeln!(out, "gcharm_pool_flushes {}", r.flushes());
        let _ = writeln!(out, "gcharm_pool_flush_deadline {}", r.flush_deadline);
        let _ = writeln!(out, "gcharm_pool_serve_offered {}", r.serve_offered);
        let _ = writeln!(out, "gcharm_pool_serve_admitted {}", r.serve_admitted);
        let _ = writeln!(out, "gcharm_pool_serve_rejected {}", r.serve_rejected);
        let _ = writeln!(out, "gcharm_pool_serve_shed {}", r.serve_shed);
        let _ = writeln!(out, "gcharm_pool_transfer_bytes {}", r.transfer_bytes);
        let _ = writeln!(out, "gcharm_pool_steals {}", r.steals);
    }
    for job in shared.live_jobs() {
        if let Some(js) = shared.job(job) {
            let m = js.metrics_snapshot();
            let j = job.0;
            let _ = writeln!(out, "gcharm_job_launches{{job=\"{j}\"}} {}", m.launches);
            let _ = writeln!(out, "gcharm_job_queued{{job=\"{j}\"}} {}", m.queued_requests);
            let _ = writeln!(out, "gcharm_job_outstanding{{job=\"{j}\"}} {}", m.outstanding);
        }
    }
    out
}
