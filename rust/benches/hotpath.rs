//! Micro-benchmarks of the coordinator hot paths (the L3 perf targets of
//! EXPERIMENTS.md section Perf): staging arena vs per-launch allocation,
//! registry dispatch vs a hardcoded enum match, combiner insert (sorted
//! and FIFO), chare-table staging, hybrid queue split, manifest JSON
//! parse, device-pool makespan scaling (N-Body + SpMV).
//!
//! The binary installs a counting global allocator so the arena-vs-naive
//! comparison reports heap allocations and allocated bytes per staged
//! chunk next to ns/op (see PERF.md).
//!
//! Besides the console tables, every measured series is recorded and
//! serialized to `BENCH_6.json` at exit (override the path with
//! `GCHARM_BENCH_JSON`, set it to `-` to skip). The file only ever
//! contains numbers this binary measured on this machine in this run —
//! nothing is baked in.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use gcharm::apps::md::{self, MdConfig};
use gcharm::apps::nbody::{self, dataset::DatasetSpec, NbodyConfig};
use gcharm::apps::spmv::{self, SpmvConfig};
use gcharm::bench::bench_ns;
use gcharm::coordinator::{
    builtin_registry, chunk_by_items, ChareId, ChareTable, CombinePolicy,
    Combiner, Config, DeviceRouter, HybridScheduler, JobId, KernelKindId,
    LaunchModePolicy, Pending, Report, ResidencyPolicy, RoutePolicy,
    SplitPolicy, Tile, WorkRequest,
};
use gcharm::runtime::kernel::TileKernel;
use gcharm::runtime::shapes::{
    INTERACTIONS, INTER_W, PARTICLE_W, PARTS_PER_BUCKET,
};
use gcharm::runtime::{default_artifacts_dir, Manifest, Payload, StagingArena};
use gcharm::util::json::Json;
use gcharm::util::Rng;

/// System allocator wrapper counting allocations and allocated bytes.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

// SAFETY: delegates verbatim to `System`; the counters are lock-free.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Everything measured this run, for the BENCH_6.json dump: rows of
/// `(series, metric, value, unit)`.
static RECORDED: Mutex<Vec<(String, String, f64, &'static str)>> =
    Mutex::new(Vec::new());

/// Record one measured value under `series`/`metric`.
fn record(series: &str, metric: &str, value: f64, unit: &'static str) {
    RECORDED
        .lock()
        .unwrap()
        .push((series.to_string(), metric.to_string(), value, unit));
}

/// `bench_ns` plus recording: every timed series lands in BENCH_6.json.
fn bench<F: FnMut()>(name: &str, batch: usize, reps: usize, f: F) -> f64 {
    let ns = bench_ns(name, batch, reps, f);
    record(name, "ns_per_op", ns, "ns");
    ns
}

/// Minimal JSON string escape (names are ASCII, but stay correct).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out
}

/// Serialize every recorded measurement to BENCH_6.json (or
/// `$GCHARM_BENCH_JSON`; `-` disables). Called once at the end of
/// `main`, so the file holds exactly what this run printed. The output
/// round-trips through `util::json::Json::parse`.
fn emit_bench_json() {
    let path = std::env::var("GCHARM_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_6.json".to_string());
    if path == "-" {
        return;
    }
    let rows = RECORDED.lock().unwrap();
    let mut out = String::new();
    out.push_str("{\n  \"bench\": \"hotpath\",\n  \"schema\": 1,\n");
    out.push_str(
        "  \"note\": \"measured on the machine that ran `cargo bench --bench \
         hotpath`; medians of repeated batches, see rust/benches/hotpath.rs\",\n",
    );
    out.push_str("  \"series\": [\n");
    for (i, (series, metric, value, unit)) in rows.iter().enumerate() {
        // fixed-point decimal keeps the hand-rolled parser happy
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"metric\": \"{}\", \"value\": {:.3}, \
             \"unit\": \"{}\"}}{}\n",
            json_escape(series),
            json_escape(metric),
            value,
            unit,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    match std::fs::write(&path, out) {
        Ok(()) => println!("wrote {} series to {path}", rows.len()),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

/// Run `f` `iters` times; report (allocations, bytes) per call.
fn allocs_per_op<F: FnMut()>(iters: u64, mut f: F) -> (f64, f64) {
    let a0 = ALLOCS.load(Ordering::Relaxed);
    let b0 = ALLOC_BYTES.load(Ordering::Relaxed);
    for _ in 0..iters {
        f();
    }
    let a = ALLOCS.load(Ordering::Relaxed) - a0;
    let b = ALLOC_BYTES.load(Ordering::Relaxed) - b0;
    (a as f64 / iters as f64, b as f64 / iters as f64)
}

fn pending(id: u64, slot: Option<u32>) -> Pending {
    Pending {
        wr: WorkRequest {
            id,
            job: JobId(0),
            chare: ChareId::new(0, 0),
            kind: KernelKindId(0),
            buffer: Some(id),
            data_items: 64,
            tag: id,
            arrival: 0.0,
            payload: Tile::default(),
        },
        slot,
        staged_bytes: 0,
    }
}

/// The pre-arena staging path: fresh zero-filled buffers, a cloned
/// constant arg, and a variant select + name clone per chunk.
fn naive_stage(
    manifest: &Manifest,
    eps2: f32,
    parts: &[f32],
    inters: &[f32],
    n: usize,
) -> (String, Vec<Vec<f32>>) {
    let v = manifest.select("gravity", n, 0).unwrap();
    let b = v.batch;
    let ps = PARTS_PER_BUCKET * PARTICLE_W;
    let is = INTERACTIONS * INTER_W;
    let mut p = vec![0.0f32; b * ps];
    let mut i = vec![0.0f32; b * is];
    p[..n * ps].copy_from_slice(&parts[..n * ps]);
    i[..n * is].copy_from_slice(&inters[..n * is]);
    (v.name.clone(), vec![p, i, vec![eps2]])
}

/// Arena vs per-launch allocation for the gravity staging hot path.
fn staging_comparison() {
    println!("\nstaging: arena vs per-launch allocation (gravity, n=104)");
    let kernel = Arc::new(TileKernel::gravity(1e-2));
    let (manifest, _) =
        Manifest::load_or_synthetic(&default_artifacts_dir()).unwrap();
    let n = 104; // the force kernel's occupancy-derived maxSize
    let payload = Payload::Tile {
        kernel: kernel.clone(),
        bufs: vec![
            vec![0.5f32; n * PARTS_PER_BUCKET * PARTICLE_W],
            vec![0.5f32; n * INTERACTIONS * INTER_W],
        ],
        batch: n,
    };
    let (parts, inters) = match &payload {
        Payload::Tile { bufs, .. } => (bufs[0].clone(), bufs[1].clone()),
        _ => unreachable!(),
    };

    let mut arena = StagingArena::new();
    // warm the arena so the comparison shows the steady state
    let c = arena
        .stage_chunk(&manifest, &payload, 0, n, &mut None)
        .unwrap();
    arena.recycle(c);

    let arena_ns = bench("arena stage_chunk (steady state)", 512, 9, || {
        let c = arena
            .stage_chunk(&manifest, &payload, 0, n, &mut None)
            .unwrap();
        std::hint::black_box(&c);
        arena.recycle(c);
    });
    let (arena_allocs, arena_bytes) = allocs_per_op(512, || {
        let c = arena
            .stage_chunk(&manifest, &payload, 0, n, &mut None)
            .unwrap();
        std::hint::black_box(&c);
        arena.recycle(c);
    });

    let naive_ns = bench("per-launch alloc staging (old path)", 512, 9, || {
        let staged = naive_stage(&manifest, 1e-2, &parts, &inters, n);
        std::hint::black_box(&staged);
    });
    let (naive_allocs, naive_bytes) = allocs_per_op(512, || {
        let staged = naive_stage(&manifest, 1e-2, &parts, &inters, n);
        std::hint::black_box(&staged);
    });
    record("arena stage_chunk (steady state)", "allocs_per_op", arena_allocs, "allocs");
    record("arena stage_chunk (steady state)", "alloc_bytes_per_op", arena_bytes, "bytes");
    record("per-launch alloc staging (old path)", "allocs_per_op", naive_allocs, "allocs");
    record("per-launch alloc staging (old path)", "alloc_bytes_per_op", naive_bytes, "bytes");

    println!(
        "  {:<24} {:>12} {:>14} {:>16} {:>16}",
        "path", "ns/op", "stagings/s", "allocs/op", "alloc bytes/op"
    );
    for (name, ns, a, b) in [
        ("arena", arena_ns, arena_allocs, arena_bytes),
        ("per-launch alloc", naive_ns, naive_allocs, naive_bytes),
    ] {
        println!(
            "  {:<24} {:>12.1} {:>14.0} {:>16.2} {:>16.0}",
            name,
            ns,
            1e9 / ns.max(1e-9),
            a,
            b
        );
    }
    println!(
        "  -> arena saves {:.2} allocs and {:.0} heap bytes per staged \
         chunk ({:+.1}% staging time)",
        naive_allocs - arena_allocs,
        naive_bytes - arena_bytes,
        (arena_ns - naive_ns) / naive_ns * 100.0
    );
    let s = arena.stats();
    println!(
        "  arena stats: {} checkouts, {} allocs, {} reuses, {} repadded \
         elems, {} variant lookups / {} memo hits",
        s.checkouts,
        s.buffer_allocs,
        s.buffer_reuses,
        s.repadded_elems,
        s.variant_lookups,
        s.variant_hits
    );
}

/// A closed three-variant enum standing in for the pre-redesign
/// `WorkKind` match: the baseline the registry's table dispatch is
/// measured against.
#[derive(Clone, Copy)]
enum OldKind {
    Force,
    Ewald,
    Md,
}

/// Registry table dispatch vs the old hardcoded enum match. The hot-path
/// question: does going through `registry.get(kind)` (a Vec index + Arc
/// deref) cost more than matching a closed enum? Target: <= 1% of the
/// launch hot path, i.e. nanoseconds.
fn registry_dispatch_comparison() {
    println!("\nregistry dispatch: table-driven vs closed enum match");
    let registry = builtin_registry(
        1e-2,
        vec![0.0; gcharm::runtime::shapes::KTABLE * gcharm::runtime::shapes::KTAB_W],
        [1.0, 0.04, 1.0],
    );
    let kinds = [KernelKindId(0), KernelKindId(1), KernelKindId(2)];
    let mut i = 0usize;
    let table_ns = bench("registry table dispatch", 65536, 9, || {
        let kind = kinds[i % 3];
        i += 1;
        let d = registry.get(kind);
        // the fields dispatch actually reads per batch
        std::hint::black_box((
            d.kernel.max_combine(),
            d.kernel.out_slot_len(),
            d.cpu_fallback,
            d.kernel.reuse_arg,
        ));
    });
    let old = [OldKind::Force, OldKind::Ewald, OldKind::Md];
    let mut j = 0usize;
    let match_ns = bench("closed enum match (old path)", 65536, 9, || {
        let k = old[j % 3];
        j += 1;
        let (max, out_slot, hybrid, reuse): (usize, usize, bool, Option<usize>) =
            match k {
                OldKind::Force => (104, 64, false, Some(0)),
                OldKind::Ewald => (65, 64, false, None),
                OldKind::Md => (208, 128, true, None),
            };
        std::hint::black_box((max, out_slot, hybrid, reuse));
    });
    println!(
        "  -> table dispatch {table_ns:.1} ns vs enum match {match_ns:.1} ns \
         ({:+.1} ns/launch; launch hot path is ~microseconds, so the \
         indirection is <=1%)",
        table_ns - match_ns
    );
}

/// Device-pool scaling on the N-Body workload: adaptive affinity+steal
/// routing vs static round-robin device assignment at 1/2/4 simulated
/// devices. The figure of merit is the *modeled makespan* — the busiest
/// device's modeled seconds (kernel + transfer) — since devices run
/// concurrently. Affinity maximizes per-device residency hits (fewer
/// transfer bytes); the idle-steal rebalancer shaves the depth imbalance
/// the rendezvous seeding leaves behind. Round-robin balances counts but
/// scatters every chare's reuse across all devices. The SpMV rows drive
/// the same table through the registry-only workload.
fn device_pool_scaling() {
    println!("\ndevice pool: N-Body modeled makespan, adaptive vs static routing");
    println!(
        "  {:<8} {:<16} {:>12} {:>10} {:>8} {:>12} {:>10}",
        "devices", "routing", "makespan s", "hit rate", "steals", "xfer MiB", "launches"
    );
    let mut makespans: Vec<(usize, &str, f64)> = Vec::new();
    for devices in [1usize, 2, 4] {
        for (name, route) in [
            ("affinity+steal", RoutePolicy::AffinitySteal),
            ("round-robin", RoutePolicy::RoundRobin),
        ] {
            let mut cfg = NbodyConfig::new(DatasetSpec::tiny());
            cfg.iters = 3;
            cfg.pieces_per_pe = 4;
            cfg.runtime = Config {
                pes: 4,
                devices,
                route,
                ..Config::default()
            };
            let r = nbody::run(&cfg).expect("nbody run");
            let makespan = r.report.device_makespan();
            println!(
                "  {:<8} {:<16} {:>12.5} {:>9.0}% {:>8} {:>12.2} {:>10}",
                devices,
                name,
                makespan,
                r.report.hit_rate() * 100.0,
                r.report.steals,
                r.report.transfer_bytes as f64 / (1 << 20) as f64,
                r.report.launches
            );
            record(
                &format!("nbody makespan ({name}, {devices} dev)"),
                "modeled_makespan",
                makespan,
                "s",
            );
            makespans.push((devices, name, makespan));
        }
    }
    for devices in [2usize, 4] {
        let get = |n: &str| {
            makespans
                .iter()
                .find(|(d, m, _)| *d == devices && *m == n)
                .map(|(_, _, s)| *s)
                .unwrap_or(0.0)
        };
        let (ad, rr) = (get("affinity+steal"), get("round-robin"));
        if rr > 0.0 {
            println!(
                "  -> {devices} devices: adaptive is {:+.1}% vs round-robin \
                 (paper fig: dynamic beats static by 8-38%)",
                (ad - rr) / rr * 100.0
            );
        }
    }

    println!("\ndevice pool: SpMV (registry-only workload) modeled makespan");
    println!(
        "  {:<8} {:>12} {:>10} {:>12} {:>14}",
        "devices", "makespan s", "launches", "residual^2", "cpu/gpu items"
    );
    for devices in [1usize, 2, 4] {
        let mut cfg = SpmvConfig::new(2048);
        cfg.iters = 3;
        cfg.runtime = Config { pes: 4, devices, ..Config::default() };
        let r = spmv::run(&cfg).expect("spmv run");
        record(
            &format!("spmv makespan ({devices} dev)"),
            "modeled_makespan",
            r.report.device_makespan(),
            "s",
        );
        println!(
            "  {:<8} {:>12.5} {:>10} {:>12.3e} {:>7}/{}",
            devices,
            r.report.device_makespan(),
            r.report.launches,
            r.residuals.last().copied().unwrap_or(0.0),
            r.report.cpu_items,
            r.report.gpu_items
        );
    }
}

/// LRU vs reuse-graph residency (ISSUE 7): the same three apps under
/// both `Config::residency` policies on a 2-device pool. N-Body stages
/// its particle buffers through the chare tables, so lookahead eviction
/// and ahead-of-flush prefetch move its hit rate and transfer/migration
/// bytes; MD and SpMV register no reuse arg and must be policy-neutral
/// (their rows pin that the knob costs nothing where it cannot help).
fn residency_ablation() {
    println!("\nresidency: LRU vs reuse-graph (lookahead eviction + prefetch)");
    println!(
        "  {:<8} {:<12} {:>9} {:>11} {:>11} {:>9} {:>10} {:>8}",
        "app", "policy", "hit rate", "xfer MiB", "migr MiB", "pf hits",
        "pf wasted", "steals"
    );
    let run_app = |app: &str, policy: ResidencyPolicy| -> Report {
        let runtime = Config {
            pes: 4,
            devices: 2,
            route: RoutePolicy::AffinitySteal,
            residency: policy,
            ..Config::default()
        };
        match app {
            "nbody" => {
                let mut cfg = NbodyConfig::new(DatasetSpec::tiny());
                cfg.iters = 3;
                cfg.pieces_per_pe = 4;
                cfg.runtime = runtime;
                nbody::run(&cfg).expect("nbody run").report
            }
            "md" => {
                let mut cfg = MdConfig::new(2048);
                cfg.steps = 4;
                cfg.runtime = runtime;
                md::run(&cfg).expect("md run").report
            }
            _ => {
                let mut cfg = SpmvConfig::new(2048);
                cfg.iters = 3;
                cfg.runtime = runtime;
                spmv::run(&cfg).expect("spmv run").report
            }
        }
    };
    const MIB: f64 = (1u64 << 20) as f64;
    for app in ["nbody", "md", "spmv"] {
        for (pname, policy) in [
            ("lru", ResidencyPolicy::Lru),
            ("reuse-graph", ResidencyPolicy::ReuseGraph),
        ] {
            let r = run_app(app, policy);
            println!(
                "  {:<8} {:<12} {:>8.0}% {:>11.2} {:>11.2} {:>9} {:>10} {:>8}",
                app,
                pname,
                r.hit_rate() * 100.0,
                r.transfer_bytes as f64 / MIB,
                r.migrated_bytes as f64 / MIB,
                r.prefetch_hits,
                r.prefetch_wasted,
                r.steals
            );
            let series = format!("{app} residency ({pname}, 2 dev)");
            record(&series, "hit_rate", r.hit_rate(), "ratio");
            record(
                &series,
                "transfer_bytes",
                r.transfer_bytes as f64,
                "bytes",
            );
            record(
                &series,
                "migrated_bytes",
                r.migrated_bytes as f64,
                "bytes",
            );
            record(&series, "prefetch_hits", r.prefetch_hits as f64, "count");
            record(
                &series,
                "prefetch_wasted",
                r.prefetch_wasted as f64,
                "count",
            );
        }
    }
    println!(
        "  -> reuse-graph vs lru: the N-Body rows carry the ablation \
         (lookahead eviction + prefetch on real reuse traffic); MD and \
         SpMV have no reuse arg, so their deltas must be noise"
    );
}

/// Per-batch vs persistent vs adaptive launch modes (ISSUE 8): nbody and
/// spmv on a 2-device pool with `CombinePolicy::StaticEvery(8)`, which
/// chops the work into many small dense flushes and makes the runs
/// launch-bound — the regime the persistent resident loop is for (each
/// dense batch pays the modeled queue-poll cost instead of the full
/// per-launch overhead). Adaptive starts per-batch (pessimistic
/// idle-share prior) and must converge onto the winning static mode, so
/// its makespan may never exceed the worse static row.
fn launch_mode_ablation() {
    println!(
        "\nlaunch mode: per-batch vs persistent vs adaptive \
         (launch-bound: StaticEvery(8), 2 devices)"
    );
    println!(
        "  {:<8} {:<12} {:>13} {:>9} {:>11} {:>10}",
        "app", "mode", "makespan ms", "launches", "persistent", "per-batch"
    );
    let run_app = |app: &str, mode: LaunchModePolicy| -> Report {
        let runtime = Config {
            pes: 4,
            devices: 2,
            route: RoutePolicy::AffinitySteal,
            combine: CombinePolicy::StaticEvery(8),
            launch_mode: mode,
            ..Config::default()
        };
        match app {
            "nbody" => {
                let mut cfg = NbodyConfig::new(DatasetSpec::tiny());
                cfg.iters = 3;
                cfg.pieces_per_pe = 4;
                cfg.runtime = runtime;
                nbody::run(&cfg).expect("nbody run").report
            }
            _ => {
                let mut cfg = SpmvConfig::new(2048);
                cfg.iters = 3;
                cfg.runtime = runtime;
                spmv::run(&cfg).expect("spmv run").report
            }
        }
    };
    for app in ["nbody", "spmv"] {
        let mut makespans = Vec::new();
        for (mname, mode) in [
            ("per-batch", LaunchModePolicy::PerBatch),
            ("persistent", LaunchModePolicy::Persistent),
            ("adaptive", LaunchModePolicy::Adaptive),
        ] {
            let r = run_app(app, mode);
            assert_eq!(
                r.persistent_batches + r.per_batch_launches,
                r.launches,
                "{app}/{mname}: launch-mode partition broke"
            );
            println!(
                "  {:<8} {:<12} {:>13.3} {:>9} {:>11} {:>10}",
                app,
                mname,
                r.device_makespan() * 1e3,
                r.launches,
                r.persistent_batches,
                r.per_batch_launches
            );
            let series = format!("{app} launch-mode ({mname}, 2 dev)");
            record(&series, "modeled_makespan", r.device_makespan(), "s");
            record(&series, "launches", r.launches as f64, "count");
            record(
                &series,
                "persistent_batches",
                r.persistent_batches as f64,
                "count",
            );
            makespans.push(r.device_makespan());
        }
        let (pb, ps, ad) = (makespans[0], makespans[1], makespans[2]);
        println!(
            "  -> {app}: persistent saves {:+.1}% vs per-batch; adaptive \
             within {:+.1}% of the better static mode",
            (pb - ps) / pb * 100.0,
            (ad - pb.min(ps)) / pb.min(ps) * 100.0
        );
        assert!(
            ps < pb,
            "{app}: persistent must win a launch-bound config \
             (persistent {ps:.6}s vs per-batch {pb:.6}s)"
        );
        // adaptive pays a short per-batch warm-up before the idle-share
        // EWMA crosses the enter threshold, so it sits between the static
        // modes — but it may never lose to the worse of the two
        assert!(
            ad <= pb.max(ps) + 1e-12,
            "{app}: adaptive lost to the worse static mode \
             (adaptive {ad:.6}s vs worse {:.6}s)",
            pb.max(ps)
        );
    }
}

fn main() {
    println!("hot-path micro-benchmarks (median ns/op)");

    staging_comparison();

    registry_dispatch_comparison();

    device_pool_scaling();

    residency_ablation();

    launch_mode_ablation();

    // device router: affinity route + steal decision per request
    {
        let mut r = DeviceRouter::new(RoutePolicy::AffinitySteal, 4, 4, 16);
        let shares = vec![0.25; 4];
        let mut i = 0u32;
        bench("device route + steal probe (4 devices)", 4096, 9, || {
            let d = r.route(JobId(0), ChareId::new(1, i % 256));
            r.note_enqueued(d, JobId(0), 1);
            std::hint::black_box(r.steal_candidate(&shares));
            r.note_completed(d, JobId(0), 1);
            i += 1;
        });
    }

    // combiner insert at a steady queue depth of ~104 (the force maxSize)
    {
        let mut rng = Rng::new(1);
        let mut c = Combiner::new(CombinePolicy::Adaptive, 104, true);
        let mut i = 0u64;
        bench("combiner insert (slot-sorted, depth<=104)", 4096, 9, || {
            c.insert(pending(i, Some(rng.below(16_384) as u32)), i as f64 * 1e-6);
            i += 1;
            if c.len() >= 104 {
                c.force_flush();
            }
        });
    }
    {
        let mut c = Combiner::new(CombinePolicy::Adaptive, 104, false);
        let mut i = 0u64;
        bench("combiner insert (fifo, depth<=104)", 4096, 9, || {
            c.insert(pending(i, None), i as f64 * 1e-6);
            i += 1;
            if c.len() >= 104 {
                c.force_flush();
            }
        });
    }

    // chare-table staging: miss-heavy and hit-heavy
    {
        let slot = PARTS_PER_BUCKET * PARTICLE_W;
        let mut t = ChareTable::new(1024, slot);
        let buf = vec![1.0f32; slot];
        let mut i = 0u64;
        bench("chare-table stage (miss-heavy)", 2048, 9, || {
            let s = t.stage_pinned(i % 4096, &buf).unwrap();
            let _ = s;
            t.release(i % 4096);
            i += 1;
        });
        let mut j = 0u64;
        bench("chare-table stage (hit-heavy)", 2048, 9, || {
            let s = t.stage_pinned(j % 64, &buf).unwrap();
            let _ = s;
            t.release(j % 64);
            j += 1;
        });
    }

    // hybrid split of a 512-request queue
    {
        let k0 = KernelKindId(0);
        let mut h = HybridScheduler::new(SplitPolicy::AdaptiveItems);
        h.record_cpu(k0, 100, 0.010);
        h.record_gpu(k0, 100, 0.002);
        bench("hybrid split (512 requests)", 256, 9, || {
            let q: Vec<Pending> = (0..512).map(|i| pending(i, None)).collect();
            let (c, g) = h.split(k0, q);
            std::hint::black_box((c.len(), g.len()));
        });
    }

    // cpu-pool chunking of a 512-request queue across 4 workers. The
    // batch is built once; each op splits it and regroups the chunks
    // (pointer moves only), so the timing tracks the split itself
    // rather than test-data construction.
    {
        let mut q: Vec<Pending> = (0..512).map(|i| pending(i, None)).collect();
        bench("cpu-pool chunk+regroup (512 reqs, 4 workers)", 256, 9, || {
            let chunks = chunk_by_items(std::mem::take(&mut q), 4);
            std::hint::black_box(chunks.len());
            q = chunks.into_iter().flatten().collect();
        });
    }

    // manifest JSON parse
    {
        let dir = default_artifacts_dir();
        if let Ok(text) = std::fs::read_to_string(dir.join("manifest.json")) {
            bench("manifest.json parse", 256, 9, || {
                std::hint::black_box(Json::parse(&text).unwrap());
            });
        }
    }

    emit_bench_json();

    println!("done");
}
