//! Micro-benchmarks of the coordinator hot paths (the L3 perf targets of
//! EXPERIMENTS.md section Perf): combiner insert (sorted and FIFO), chare-table
//! staging, hybrid queue split, manifest JSON parse.

use gcharm::bench::bench_ns;
use gcharm::coordinator::{
    ChareId, ChareTable, CombinePolicy, Combiner, HybridScheduler, Pending,
    SplitPolicy, WorkKind, WorkRequest, WrPayload,
};
use gcharm::runtime::shapes::{PARTICLE_W, PARTS_PER_BUCKET};
use gcharm::util::json::Json;
use gcharm::util::Rng;

fn pending(id: u64, slot: Option<u32>) -> Pending {
    Pending {
        wr: WorkRequest {
            id,
            chare: ChareId::new(0, 0),
            kind: WorkKind::Force,
            buffer: Some(id),
            data_items: 64,
            tag: id,
            arrival: 0.0,
            payload: WrPayload::Ewald { parts: vec![] },
        },
        slot,
        staged_bytes: 0,
    }
}

fn main() {
    println!("hot-path micro-benchmarks (median ns/op)");

    // combiner insert at a steady queue depth of ~104 (the force maxSize)
    {
        let mut rng = Rng::new(1);
        let mut c = Combiner::new(CombinePolicy::Adaptive, 104, true);
        let mut i = 0u64;
        bench_ns("combiner insert (slot-sorted, depth<=104)", 4096, 9, || {
            c.insert(pending(i, Some(rng.below(16_384) as u32)), i as f64 * 1e-6);
            i += 1;
            if c.len() >= 104 {
                c.force_flush();
            }
        });
    }
    {
        let mut c = Combiner::new(CombinePolicy::Adaptive, 104, false);
        let mut i = 0u64;
        bench_ns("combiner insert (fifo, depth<=104)", 4096, 9, || {
            c.insert(pending(i, None), i as f64 * 1e-6);
            i += 1;
            if c.len() >= 104 {
                c.force_flush();
            }
        });
    }

    // chare-table staging: miss-heavy and hit-heavy
    {
        let mut t = ChareTable::new(1024);
        let buf = vec![1.0f32; PARTS_PER_BUCKET * PARTICLE_W];
        let mut i = 0u64;
        bench_ns("chare-table stage (miss-heavy)", 2048, 9, || {
            let s = t.stage_pinned(i % 4096, &buf).unwrap();
            let _ = s;
            t.release(i % 4096);
            i += 1;
        });
        let mut j = 0u64;
        bench_ns("chare-table stage (hit-heavy)", 2048, 9, || {
            let s = t.stage_pinned(j % 64, &buf).unwrap();
            let _ = s;
            t.release(j % 64);
            j += 1;
        });
    }

    // hybrid split of a 512-request queue
    {
        let mut h = HybridScheduler::new(SplitPolicy::AdaptiveItems);
        h.record_cpu(100, 0.010);
        h.record_gpu(100, 0.002);
        bench_ns("hybrid split (512 requests)", 256, 9, || {
            let q: Vec<Pending> = (0..512).map(|i| pending(i, None)).collect();
            let (c, g) = h.split(q);
            std::hint::black_box((c.len(), g.len()));
        });
    }

    // manifest JSON parse
    {
        let dir = gcharm::runtime::default_artifacts_dir();
        if let Ok(text) = std::fs::read_to_string(dir.join("manifest.json")) {
            bench_ns("manifest.json parse", 256, 9, || {
                std::hint::black_box(Json::parse(&text).unwrap());
            });
        }
    }

    println!("done");
}
