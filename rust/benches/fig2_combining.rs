//! Regenerates paper Figure 2: dynamic vs static combining strategies for
//! the small (cube300-like) and large (lambs-like) ChaNGa datasets.
//! Set GCHARM_BENCH_FULL=1 for the full-scale run (slower).

fn main() {
    let scale = if std::env::var("GCHARM_BENCH_FULL").is_ok() {
        gcharm::bench::Scale::full()
    } else {
        gcharm::bench::Scale::quick()
    };
    gcharm::bench::print_occupancy_table();
    gcharm::bench::run_fig2(&scale);
}
