//! Serving-tier load benchmark (ISSUE 10): open-loop Poisson bursts
//! with heavy-tailed job sizes driven through a `serve::ServeFront`,
//! reporting per-class p50/p99 completion latency under the four
//! ablations {deadline-flush on/off} x {shed on/off}.
//!
//! The arrival trace is a pure function of its seed — a seeded
//! `util::Rng` draws inter-arrival gaps, burst widths, classes, and
//! sizes; no wall clock touches the generator — so all four ablations
//! replay the identical offered load and their tails are directly
//! comparable. Wall-clock `Instant` is used only to pace the open-loop
//! offers and to measure each admitted job's completion latency.
//!
//! `GCHARM_SMOKE=1` shrinks the trace for CI; results are serialized to
//! `BENCH_SERVE.json` (override with `GCHARM_BENCH_JSON`, `-` skips).

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use gcharm::coordinator::{
    Chare, ChareId, Config, Ctx, JobSpec, KernelDescriptor, KernelKindId,
    Msg, Runtime, Tile, WorkDraft, WrResult, METHOD_RESULT,
};
use gcharm::runtime::kernel::{TileArgSpec, TileKernel};
use gcharm::runtime::KernelResources;
use gcharm::serve::{
    Admission, AdmissionPolicy, QosClass, ServeConfig, ServeFront,
};
use gcharm::util::Rng;

const METHOD_GO: u32 = 1;
const ROWS: usize = 4;

/// Per-slot kernel: sum of the tile entries.
fn sum_slot(args: &[&[f32]], _c: &[f32]) -> Vec<f32> {
    vec![args[0].iter().sum()]
}

/// The shared synthetic family every offered job submits against (one
/// family, so cross-job combining is live and the classes actually
/// contend in the combiners).
fn descriptor() -> KernelDescriptor {
    KernelDescriptor {
        kernel: Arc::new(TileKernel {
            name: Arc::from("serve_load"),
            args: vec![TileArgSpec {
                name: "tile",
                rows: ROWS,
                width: 1,
                pad: 0.0,
            }],
            constant: Arc::new(Vec::new()),
            out_rows: 1,
            out_width: 1,
            resources: KernelResources {
                threads_per_block: 128,
                regs_per_thread: 64,
                smem_per_block: 4096,
            },
            items_per_slot: ROWS as u64,
            reuse_arg: None,
            gather_name: None,
            entry_arg: None,
            slot_fn: sum_slot,
        }),
        combine: None,
        sort_by_slot: false,
        cpu_fallback: false,
        launch_mode: None,
    }
}

/// A chare bursting `count` all-ones requests per GO and contributing
/// the summed outputs (exact: `count * ROWS` per round).
struct Burster {
    id: ChareId,
    count: usize,
    pending: usize,
    sum: f64,
}

impl Chare for Burster {
    fn receive(&mut self, msg: Msg, ctx: &mut Ctx) {
        match msg.method {
            METHOD_GO => {
                let kind: KernelKindId = msg.take();
                self.pending = self.count;
                self.sum = 0.0;
                for i in 0..self.count {
                    ctx.submit(WorkDraft {
                        chare: self.id,
                        kind,
                        buffer: None,
                        data_items: ROWS,
                        tag: i as u64,
                        payload: Tile::new(vec![vec![1.0; ROWS]]),
                    })
                    .expect("registered tile shape");
                }
            }
            METHOD_RESULT => {
                let r: WrResult = msg.take();
                self.sum += r.out[0] as f64;
                self.pending -= 1;
                if self.pending == 0 {
                    ctx.contribute(self.sum);
                }
            }
            other => panic!("unknown method {other}"),
        }
    }
}

fn job_spec(name: String, count: usize) -> JobSpec {
    let id = ChareId::new(9, 0);
    JobSpec::new(name)
        .kernel(descriptor())
        .chare(id, 0, Box::new(Burster { id, count, pending: 0, sum: 0.0 }))
        .driver(move |ctx| {
            let kind = ctx.kinds()[0];
            ctx.send(id, Msg::new(METHOD_GO, kind));
            let v = ctx.await_reduction(1)?;
            ctx.await_quiescence();
            Ok(vec![v])
        })
}

/// One scheduled offer of the seeded trace.
struct Arrival {
    /// Offset from the run start, seconds.
    at: f64,
    class: QosClass,
    /// Requests the job bursts (heavy-tailed).
    count: usize,
}

/// The open-loop trace: a Poisson arrival process (exponential gaps)
/// with occasional bursts (several offers at one instant) and Pareto
/// job sizes. Pure function of `seed` — the four ablations replay it
/// bit-identically.
fn trace(seed: u64, offers: usize, mean_gap: f64) -> Vec<Arrival> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(offers);
    let mut t = 0.0;
    while out.len() < offers {
        t += rng.exponential(mean_gap);
        // one in five gaps opens a burst of 2-5 coincident offers
        let width = if rng.below(5) == 0 { 2 + rng.below(4) } else { 1 };
        for _ in 0..width.min(offers - out.len()) {
            let class = match rng.below(10) {
                0..=2 => QosClass::LatencySensitive,
                3..=7 => QosClass::Throughput,
                _ => QosClass::BestEffort,
            };
            // Pareto (alpha 1.3) via inverse transform, clamped: most
            // jobs small, a heavy tail of large ones
            let u = 1.0 - rng.f64();
            let count =
                (8.0 * u.powf(-1.0 / 1.3)).clamp(8.0, 400.0) as usize;
            out.push(Arrival { at: t, class, count });
        }
    }
    out
}

/// Latency percentile (seconds) of a sorted sample set.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let i = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[i]
}

/// Per-class results of one ablation run.
struct RunResult {
    /// Sorted completion latencies (seconds), indexed by class.
    latencies: [Vec<f64>; 3],
    rejected: u64,
    shed: u64,
}

/// Replay `arrivals` open-loop against a fresh runtime + front end.
fn run(arrivals: &[Arrival], deadline: bool, shed: bool) -> RunResult {
    let rt = Runtime::new(Config { pes: 2, ..Config::default() }).unwrap();
    let front = Arc::new(
        ServeFront::new(ServeConfig {
            policy: if shed {
                AdmissionPolicy::Shed
            } else {
                AdmissionPolicy::Reject
            },
            class_depth: [4, 4, 4],
            pool_depth: 6,
            deadline: deadline.then_some(0.005),
        })
        .unwrap(),
    );
    let done: Arc<Mutex<Vec<(usize, f64)>>> =
        Arc::new(Mutex::new(Vec::new()));
    let start = Instant::now();
    let mut rejected = 0u64;
    let mut shed_n = 0u64;
    std::thread::scope(|s| {
        for (n, a) in arrivals.iter().enumerate() {
            // open loop: offer at the scheduled instant no matter how
            // the pool is doing
            let due = Duration::from_secs_f64(a.at);
            if let Some(wait) = due.checked_sub(start.elapsed()) {
                std::thread::sleep(wait);
            }
            let offered_at = Instant::now();
            match front
                .offer(&rt, a.class, job_spec(format!("j{n}"), a.count))
                .unwrap()
            {
                Admission::Admitted(h) => {
                    let done = done.clone();
                    let class = a.class.index();
                    s.spawn(move || {
                        // preempted jobs seal Cancelled with an empty
                        // series: only real completions count
                        if let Ok(r) = h.wait() {
                            if !r.series.is_empty() {
                                done.lock().unwrap().push((
                                    class,
                                    offered_at.elapsed().as_secs_f64(),
                                ));
                            }
                        }
                    });
                }
                Admission::Rejected => rejected += 1,
                Admission::Shed => shed_n += 1,
            }
        }
    });
    front.drain();
    let stats = front.stats();
    assert!(stats.ledger_closes(), "admission ledger must close:\n{stats}");
    rt.shutdown();
    let mut latencies: [Vec<f64>; 3] = Default::default();
    for (class, secs) in done.lock().unwrap().iter() {
        latencies[*class].push(*secs);
    }
    for l in &mut latencies {
        l.sort_by(|a, b| a.partial_cmp(b).unwrap());
    }
    RunResult { latencies, rejected, shed: shed_n }
}

/// Everything measured this run, for the JSON dump.
static RECORDED: Mutex<Vec<(String, String, f64, &'static str)>> =
    Mutex::new(Vec::new());

fn record(series: &str, metric: &str, value: f64, unit: &'static str) {
    RECORDED
        .lock()
        .unwrap()
        .push((series.to_string(), metric.to_string(), value, unit));
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out
}

/// Serialize every recorded measurement (same shape as the hotpath
/// bench's dump; only numbers this run measured on this machine).
fn emit_bench_json() {
    let path = std::env::var("GCHARM_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_SERVE.json".to_string());
    if path == "-" {
        return;
    }
    let rows = RECORDED.lock().unwrap();
    let mut out = String::new();
    out.push_str("{\n  \"bench\": \"serve_load\",\n  \"schema\": 1,\n");
    out.push_str(
        "  \"note\": \"measured on the machine that ran `cargo bench \
         --bench serve_load`; seeded open-loop trace, see \
         rust/benches/serve_load.rs\",\n",
    );
    out.push_str("  \"series\": [\n");
    for (i, (series, metric, value, unit)) in rows.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"metric\": \"{}\", \"value\": {:.6}, \
             \"unit\": \"{}\"}}{}\n",
            json_escape(series),
            json_escape(metric),
            value,
            unit,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    match std::fs::write(&path, out) {
        Ok(()) => println!("wrote {} series to {path}", rows.len()),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    let smoke = std::env::var("GCHARM_SMOKE").is_ok();
    let (offers, mean_gap) = if smoke { (24, 0.004) } else { (160, 0.002) };
    let seed = 42u64;
    let arrivals = trace(seed, offers, mean_gap);
    println!(
        "serve_load: {} offers over {:.3}s of trace (seed {seed}{})",
        arrivals.len(),
        arrivals.last().map_or(0.0, |a| a.at),
        if smoke { ", smoke" } else { "" }
    );

    let mut latency_p99 = [[0.0f64; 2]; 2]; // [deadline][shed]
    for (deadline, shed) in
        [(false, false), (false, true), (true, false), (true, true)]
    {
        let tag = format!(
            "deadline={} shed={}",
            if deadline { "on" } else { "off" },
            if shed { "on" } else { "off" }
        );
        let r = run(&arrivals, deadline, shed);
        println!("-- {tag}: rejected {} shed {}", r.rejected, r.shed);
        for c in QosClass::ALL {
            let l = &r.latencies[c.index()];
            let p50 = percentile(l, 0.50);
            let p99 = percentile(l, 0.99);
            println!(
                "   {:<12} n={:<4} p50 {:>8.3}ms  p99 {:>8.3}ms",
                c.name(),
                l.len(),
                p50 * 1e3,
                p99 * 1e3
            );
            let series = format!("{tag} {}", c.name());
            record(&series, "completions", l.len() as f64, "jobs");
            record(&series, "latency_p50", p50 * 1e3, "ms");
            record(&series, "latency_p99", p99 * 1e3, "ms");
        }
        record(&tag, "rejected", r.rejected as f64, "jobs");
        record(&tag, "shed", r.shed as f64, "jobs");
        latency_p99[usize::from(deadline)][usize::from(shed)] =
            percentile(&r.latencies[QosClass::LatencySensitive.index()], 0.99);
    }

    // The ISSUE 10 acceptance comparison: the full stack (deadline
    // flush + shed) must not worsen the latency class's p99 against
    // both knobs off, on the identical offered trace. Reported, not
    // asserted — single-run tails are noisy; BENCH_SERVE.json carries
    // the numbers for the repeated-run comparison.
    let on = latency_p99[1][1];
    let off = latency_p99[0][0];
    println!(
        "latency p99: full stack {:.3}ms vs both-off {:.3}ms -> {}",
        on * 1e3,
        off * 1e3,
        if on <= off * 1.05 { "ok" } else { "WORSE (rerun: noisy tail?)" }
    );
    record("ablation", "latency_p99_full_stack", on * 1e3, "ms");
    record("ablation", "latency_p99_both_off", off * 1e3, "ms");

    emit_bench_json();
    println!("done");
}
