//! Ablation bench over the adaptive combiner's design parameters
//! (occupancy-derived combine target). See DESIGN.md section 4.

fn main() {
    let scale = if std::env::var("GCHARM_BENCH_FULL").is_ok() {
        gcharm::bench::Scale::full()
    } else {
        gcharm::bench::Scale::quick()
    };
    gcharm::bench::run_ablation(&scale);
}
