//! Regenerates paper Figure 3: GPU kernel and data-transfer times under
//! no-reuse / reuse / reuse+sorted-coalescing for the large dataset.
//! Set GCHARM_BENCH_FULL=1 for the full-scale run.

fn main() {
    let scale = if std::env::var("GCHARM_BENCH_FULL").is_ok() {
        gcharm::bench::Scale::full()
    } else {
        gcharm::bench::Scale::quick()
    };
    gcharm::bench::run_fig3(&scale);
}
