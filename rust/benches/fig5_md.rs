//! Regenerates paper Figure 5: MD total execution times with static vs
//! adaptive dynamic scheduling, over a particle-count sweep.
//! Set GCHARM_BENCH_FULL=1 for the full-scale run.

fn main() {
    let scale = if std::env::var("GCHARM_BENCH_FULL").is_ok() {
        gcharm::bench::Scale::full()
    } else {
        gcharm::bench::Scale::quick()
    };
    gcharm::bench::run_fig5(&scale);
}
