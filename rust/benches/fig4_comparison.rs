//! Regenerates paper Figure 4: adaptive strategies vs static strategies vs
//! the hand-tuned hybrid code vs CPU-only, across 1-8 PEs.
//! Set GCHARM_BENCH_FULL=1 for the full-scale run.

fn main() {
    let scale = if std::env::var("GCHARM_BENCH_FULL").is_ok() {
        gcharm::bench::Scale::full()
    } else {
        gcharm::bench::Scale::quick()
    };
    gcharm::bench::run_fig4(&scale);
}
