//! Integration: AOT artifacts -> PJRT engine -> numerics.
//!
//! Loads the real artifacts built by `make artifacts` (or the synthetic
//! ladder), executes the registered kernel families from rust with
//! hand-computable inputs, and checks the physics -- proving the
//! python-AOT -> rust-load bridge end to end through the open registry
//! surface.

use std::sync::Arc;

use gcharm::runtime::kernel::TileKernel;
use gcharm::runtime::shapes::{
    INTERACTIONS, INTER_W, KTAB_W, KTABLE, MD_PAD_POS, MD_W, OUT_W,
    PARTICLE_W, PARTS_PER_BUCKET, PARTS_PER_PATCH,
};
use gcharm::runtime::{
    default_artifacts_dir, CoalescingClass, Executor, LaunchMode, LaunchSpec,
    Payload,
};

const EPS2: f32 = 1e-2;
const MD_PARAMS: [f32; 3] = [1.0, 0.04, 1.0];

fn ktab() -> Vec<f32> {
    // one active k-vector: k = (1, 0, 0), coef = 0.5
    let mut ktab = vec![0.0f32; KTABLE * KTAB_W];
    ktab[0] = 1.0;
    ktab[3] = 0.5;
    ktab
}

fn gravity() -> Arc<TileKernel> {
    Arc::new(TileKernel::gravity(EPS2))
}

fn ewald() -> Arc<TileKernel> {
    Arc::new(TileKernel::ewald(ktab()))
}

fn md() -> Arc<TileKernel> {
    Arc::new(TileKernel::md_force(MD_PARAMS))
}

fn executor() -> Executor {
    Executor::new(&default_artifacts_dir(), vec![gravity(), ewald(), md()])
        .expect("run `make artifacts` before cargo test")
}

fn gravity_payload(batch: usize) -> Payload {
    // bucket b: particle 0 at origin mass 1; interaction 0 at (1+b, 0, 0)
    // with mass 2. Everything else is massless padding.
    let mut parts = vec![0.0f32; batch * PARTS_PER_BUCKET * PARTICLE_W];
    let mut inters = vec![0.0f32; batch * INTERACTIONS * INTER_W];
    for b in 0..batch {
        parts[b * PARTS_PER_BUCKET * PARTICLE_W + 3] = 1.0; // mass
        let o = b * INTERACTIONS * INTER_W;
        inters[o] = 1.0 + b as f32;
        inters[o + 3] = 2.0;
    }
    Payload::Tile { kernel: gravity(), bufs: vec![parts, inters], batch }
}

fn expected_ax(r: f32) -> f32 {
    // a_x = m * r / (r^2 + eps2)^{3/2}
    2.0 * r / (r * r + EPS2).powf(1.5)
}

#[test]
fn gravity_kernel_numerics() {
    let mut ex = executor();
    let done = ex
        .run(LaunchSpec {
            id: 1,
            payload: gravity_payload(3),
            transfer_bytes: 0,
            pattern: CoalescingClass::Contiguous,
            mode: LaunchMode::PerBatch,
        })
        .unwrap();
    assert_eq!(done.batch, 3);
    assert_eq!(done.out.len(), 3 * PARTS_PER_BUCKET * OUT_W);
    for b in 0..3 {
        let o = b * PARTS_PER_BUCKET * OUT_W;
        let want = expected_ax(1.0 + b as f32);
        let got = done.out[o];
        assert!(
            (got - want).abs() < 1e-4 * want.max(1.0),
            "bucket {b}: ax = {got}, want {want}"
        );
        // no force off-axis
        assert!(done.out[o + 1].abs() < 1e-6);
        assert!(done.out[o + 2].abs() < 1e-6);
        // potential is negative
        assert!(done.out[o + 3] < 0.0);
        // padding particles' rows are finite
        assert!(done.out[o + 4].is_finite());
    }
}

#[test]
fn gravity_batch_exceeding_ladder_splits() {
    // largest gravity variant is B128; 150 forces a split launch
    let mut ex = executor();
    let done = ex
        .run(LaunchSpec {
            id: 2,
            payload: gravity_payload(150),
            transfer_bytes: 0,
            pattern: CoalescingClass::Contiguous,
            mode: LaunchMode::PerBatch,
        })
        .unwrap();
    assert_eq!(done.batch, 150);
    assert_eq!(done.out.len(), 150 * PARTS_PER_BUCKET * OUT_W);
    // bucket 149: interaction at distance 150
    let o = 149 * PARTS_PER_BUCKET * OUT_W;
    let want = expected_ax(150.0);
    assert!((done.out[o] - want).abs() < 1e-4 * want.max(1e-6));
    assert!(ex.launches() >= 2, "expected a split launch");
}

#[test]
fn gather_kernel_matches_contiguous() {
    let mut ex = executor();
    let batch = 4;

    // Build a pool holding each bucket's particles at scattered rows, and
    // an index array pointing at them; physics must equal the contiguous
    // layout's.
    let contiguous = gravity_payload(batch);
    let (parts, inters) = match &contiguous {
        Payload::Tile { bufs, .. } => (bufs[0].clone(), bufs[1].clone()),
        _ => unreachable!(),
    };

    let rows = 512;
    let mut pool = vec![0.0f32; rows * PARTICLE_W];
    let mut idx = vec![0i32; batch * PARTS_PER_BUCKET];
    // scatter with a stride that shuffles order
    for (i, chunk) in parts.chunks(PARTICLE_W).enumerate() {
        let row = (i * 37 + 11) % rows;
        pool[row * PARTICLE_W..row * PARTICLE_W + PARTICLE_W]
            .copy_from_slice(chunk);
        idx[i] = row as i32;
    }

    let a = ex
        .run(LaunchSpec {
            id: 3,
            payload: contiguous,
            transfer_bytes: 0,
            pattern: CoalescingClass::Contiguous,
            mode: LaunchMode::PerBatch,
        })
        .unwrap();
    let b = ex
        .run(LaunchSpec {
            id: 4,
            payload: Payload::TileGather {
                kernel: gravity(),
                pool: std::sync::Arc::new(pool),
                idx,
                bufs: vec![inters],
                batch,
            },
            transfer_bytes: 0,
            pattern: CoalescingClass::RandomGather,
            mode: LaunchMode::PerBatch,
        })
        .unwrap();
    assert_eq!(a.out.len(), b.out.len());
    for (x, y) in a.out.iter().zip(&b.out) {
        assert!((x - y).abs() < 1e-4, "{x} vs {y}");
    }
    // modeled kernel time must be strictly larger for the gather pattern
    assert!(b.modeled.kernel > a.modeled.kernel);
}

#[test]
fn ewald_kernel_numerics() {
    let mut ex = executor();
    // particle at x = pi/2, mass 3: force_x = m * coef * sin(k.x) * kx
    //                               pot = m * coef * cos(k.x)
    let batch = 1;
    let mut parts = vec![0.0f32; PARTS_PER_BUCKET * PARTICLE_W];
    parts[0] = std::f32::consts::FRAC_PI_2;
    parts[3] = 3.0;
    let done = ex
        .run(LaunchSpec {
            id: 5,
            payload: Payload::Tile {
                kernel: ewald(),
                bufs: vec![parts],
                batch,
            },
            transfer_bytes: 0,
            pattern: CoalescingClass::Contiguous,
            mode: LaunchMode::PerBatch,
        })
        .unwrap();
    let fx = done.out[0];
    let pot = done.out[3];
    assert!((fx - 3.0 * 0.5).abs() < 1e-4, "fx = {fx}");
    assert!(pot.abs() < 1e-4, "pot = {pot}");
}

#[test]
fn md_kernel_numerics() {
    let mut ex = executor();
    // two particles at distance 0.4 with sigma^2 = 0.04, eps = 1:
    // s6 = (0.04/0.16)^3, F = 24*(2*s6^2 - s6)/0.16 * dx
    let n = PARTS_PER_PATCH;
    let mut pa = vec![MD_PAD_POS; n * MD_W];
    let mut pb = vec![MD_PAD_POS; n * MD_W];
    pa[0] = 0.0;
    pa[1] = 0.0;
    pb[0] = 0.4;
    pb[1] = 0.0;
    let done = ex
        .run(LaunchSpec {
            id: 6,
            payload: Payload::Tile {
                kernel: md(),
                bufs: vec![pa, pb],
                batch: 1,
            },
            transfer_bytes: 0,
            pattern: CoalescingClass::Contiguous,
            mode: LaunchMode::PerBatch,
        })
        .unwrap();
    let s6 = (0.04f32 / 0.16).powi(3);
    let f = 24.0 * (2.0 * s6 * s6 - s6) / 0.16;
    let want_fx = f * (0.0 - 0.4);
    let got = done.out[0];
    assert!(
        (got - want_fx).abs() < 1e-3 * want_fx.abs(),
        "fx = {got}, want {want_fx}"
    );
    assert!(got > 0.0, "LJ well at 2*sigma is attractive: fx should be +");
    // padding rows feel nothing
    assert!(done.out[MD_W].abs() < 1e-6);
}

#[test]
fn modeled_costs_populate() {
    let mut ex = executor();
    let done = ex
        .run(LaunchSpec {
            id: 7,
            payload: gravity_payload(104),
            transfer_bytes: 104 * 1024,
            pattern: CoalescingClass::Contiguous,
            mode: LaunchMode::PerBatch,
        })
        .unwrap();
    assert!(done.modeled.transfer > 0.0);
    assert!(done.modeled.kernel > 0.0);
    assert!(done.wall > 0.0);
}

#[test]
fn ktab_constants_have_expected_layout() {
    // guard: test assumptions about KTABLE layout used in executor()
    assert_eq!(KTABLE * KTAB_W, 256);
    assert_eq!(INTERACTIONS, 128);
    assert_eq!(PARTS_PER_BUCKET, 16);
}

#[test]
fn gpu_service_roundtrip() {
    use std::sync::mpsc::channel;
    let (done_tx, done_rx) = channel();
    let svc = gcharm::runtime::GpuService::spawn(
        &default_artifacts_dir(),
        vec![gravity(), ewald(), md()],
        done_tx,
    )
    .unwrap();
    for id in 0..4u64 {
        svc.submit(LaunchSpec {
            id,
            payload: gravity_payload(2),
            transfer_bytes: 1024,
            pattern: CoalescingClass::Contiguous,
            mode: LaunchMode::PerBatch,
        })
        .unwrap();
    }
    let mut seen = Vec::new();
    for _ in 0..4 {
        let c = done_rx
            .recv_timeout(std::time::Duration::from_secs(60))
            .expect("completion")
            .expect("launch ok");
        assert_eq!(c.batch, 2);
        seen.push(c.id);
    }
    seen.sort_unstable();
    assert_eq!(seen, vec![0, 1, 2, 3]);
}
