//! The persistent multi-tenant runtime: concurrent jobs, cross-job
//! combining, per-job accounting, cancellation, and live metrics.
//!
//! Invariants covered:
//!   - two concurrent jobs of *different* families: per-job
//!     request/item/byte counters sum exactly to the `PoolReport`
//!     totals (burst accounting), no cross-job launches;
//!   - two concurrent jobs of the *same* family: the combiners merge
//!     tiles from both jobs into shared launches
//!     (`PoolReport::cross_job_launches >= 1`) and both jobs' physics
//!     stay correct;
//!   - identical kernel registrations resolve to one shared kind id,
//!     incompatible ones are rejected at `submit_job`;
//!   - `JobHandle::cancel` wakes a blocked driver, drains in-flight
//!     work, and seals a `Cancelled` job without disturbing co-tenants;
//!   - a panicking driver still seals (as `Failed`) instead of hanging
//!     the runtime's shutdown;
//!   - `metrics_snapshot` agrees with the sealed report after the job
//!     completes.

mod common;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier};

use common::{synth_descriptor, BurstJob, Burster, METHOD_GO};
use gcharm::coordinator::{
    ChareId, Config, JobSpec, JobStatus, Msg, Runtime,
};

/// Both tenants deliberately use the SAME chare id: ids are namespaced
/// per job.
const SHARED_ID: ChareId = ChareId { collection: 7, index: 0 };

fn burst(
    name: &'static str,
    family: &str,
    rows: usize,
    count: usize,
    rounds: usize,
    barrier: Option<Arc<Barrier>>,
) -> JobSpec {
    BurstJob {
        name,
        desc: synth_descriptor(family, rows),
        id: SHARED_ID,
        pe: 0,
        rows,
        count,
        rounds,
        barrier,
    }
    .spec()
}

#[test]
fn per_job_counters_sum_to_pool_totals() {
    // two jobs of DIFFERENT families: never share a launch, so even the
    // per-job launch counters sum to the pool total
    let rt = Runtime::new(Config { pes: 2, ..Config::default() }).unwrap();
    let a = rt
        .submit_job(burst("burst-a", "synth_a", 4, 220, 2, None))
        .unwrap();
    let b = rt
        .submit_job(burst("burst-b", "synth_b", 8, 150, 2, None))
        .unwrap();
    let ra = a.wait().unwrap();
    let rb = b.wait().unwrap();
    let pool = rt.shutdown();

    // physics: each request sums a tile of ones
    for s in &ra.series {
        assert_eq!(*s, (220 * 4) as f64);
    }
    for s in &rb.series {
        assert_eq!(*s, (150 * 8) as f64);
    }

    assert_eq!(pool.jobs.len(), 2);
    let ja = pool.job("burst-a").unwrap();
    let jb = pool.job("burst-b").unwrap();
    assert_eq!(ja.gpu_requests, 2 * 220);
    assert_eq!(jb.gpu_requests, 2 * 150);
    assert_eq!(
        ja.gpu_requests + jb.gpu_requests,
        pool.gpu_requests,
        "per-job requests must sum to the pool total"
    );
    assert_eq!(
        ja.gpu_items + jb.gpu_items,
        pool.gpu_items,
        "per-job items must sum to the pool total"
    );
    assert_eq!(
        ja.transfer_bytes + jb.transfer_bytes,
        pool.transfer_bytes,
        "per-item byte attribution must be exact"
    );
    assert_eq!(
        ja.launches + jb.launches,
        pool.launches,
        "distinct families never share launches"
    );
    assert_eq!(pool.cross_job_launches, 0);
    assert_eq!(ja.cross_job_launches + jb.cross_job_launches, 0);

    // the sealed report agrees with the wait()-returned one
    assert_eq!(ja.gpu_requests, ra.gpu_requests);
    assert_eq!(ja.transfer_bytes, ra.transfer_bytes);
}

#[test]
fn same_family_jobs_cross_combine() {
    // two jobs of the SAME family, bursts synchronized by a barrier:
    // the shared combiner must merge tiles from both jobs into at least
    // one launch, and the weighted-fair take must not corrupt either
    // job's sums
    let rt = Runtime::new(Config { pes: 2, ..Config::default() }).unwrap();
    let barrier = Arc::new(Barrier::new(2));
    let rounds = 6;
    let count = 400;
    let a = rt
        .submit_job(burst(
            "tenant-a",
            "synth_shared",
            4,
            count,
            rounds,
            Some(barrier.clone()),
        ))
        .unwrap();
    let b = rt
        .submit_job(burst(
            "tenant-b",
            "synth_shared",
            4,
            count,
            rounds,
            Some(barrier),
        ))
        .unwrap();
    let ra = a.wait().unwrap();
    let rb = b.wait().unwrap();
    let pool = rt.shutdown();

    // identical registration resolved to ONE kind: one kind-stats row
    assert_eq!(
        pool.kind_stats.iter().filter(|k| k.name == "synth_shared").count(),
        1
    );
    // physics survived the shared launches
    for s in ra.series.iter().chain(&rb.series) {
        assert_eq!(*s, (count * 4) as f64);
    }
    assert!(
        pool.cross_job_launches >= 1,
        "synchronized same-family bursts must cross-combine at least \
         once (got 0 over {} launches)",
        pool.launches
    );
    assert_eq!(
        pool.jobs.iter().map(|j| j.gpu_requests).sum::<u64>(),
        pool.gpu_requests
    );
    assert_eq!(
        pool.jobs.iter().map(|j| j.transfer_bytes).sum::<u64>(),
        pool.transfer_bytes,
        "byte attribution stays exact under cross-job combining"
    );
    // per-job cross-job counters saw the shared launches too
    assert!(
        pool.jobs.iter().any(|j| j.cross_job_launches >= 1),
        "shared launches must appear in the participants' reports"
    );
}

#[test]
fn incompatible_re_registration_is_rejected_at_submit() {
    let rt = Runtime::new(Config { pes: 1, ..Config::default() }).unwrap();
    let a = rt
        .submit_job(burst("ok", "synth_dup", 4, 10, 1, None))
        .unwrap();
    a.wait().unwrap();
    // same name, different tile shape: sharing the kind would corrupt
    // both jobs
    let err = rt
        .submit_job(burst("bad", "synth_dup", 8, 10, 1, None))
        .unwrap_err();
    assert!(err.to_string().contains("bad"), "{err}");
    rt.shutdown();
}

#[test]
fn cancel_wakes_driver_and_seals_cancelled() {
    let rt = Runtime::new(Config { pes: 2, ..Config::default() }).unwrap();
    let rounds_done = Arc::new(AtomicU64::new(0));
    let probe = rounds_done.clone();
    let id = ChareId::new(9, 0);
    let stuck = rt
        .submit_job(
            JobSpec::new("stuck")
                .kernel(synth_descriptor("synth_stuck", 4))
                .chare(
                    id,
                    0,
                    Box::new(Burster {
                        id,
                        rows: 4,
                        count: 50,
                        pending: 0,
                        sum: 0.0,
                    }),
                )
                .driver(move |ctx| {
                    let kind = ctx.kinds()[0];
                    let mut series = Vec::new();
                    // far more rounds than the test will allow
                    for _ in 0..1_000_000 {
                        ctx.send(id, Msg::new(METHOD_GO, kind));
                        series.push(ctx.await_reduction(1)?);
                        ctx.await_quiescence();
                        probe.fetch_add(1, Ordering::SeqCst);
                    }
                    Ok(series)
                }),
        )
        .unwrap();

    // let it make some progress, then cancel
    while rounds_done.load(Ordering::SeqCst) < 2 {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    assert_eq!(stuck.poll(), JobStatus::Running);
    stuck.cancel();
    let report = stuck.wait().expect("cancelled jobs still seal a report");
    assert!(report.gpu_requests >= 2 * 50, "progress before the cancel");
    assert!(
        report.series.is_empty(),
        "a cancelled driver's series is dropped"
    );

    // a co-tenant submitted after the cancel is unaffected
    let after = rt
        .submit_job(burst("after", "synth_after", 4, 30, 1, None))
        .unwrap();
    let ra = after.wait().unwrap();
    assert_eq!(ra.series, vec![(30 * 4) as f64]);
    let pool = rt.shutdown();
    assert_eq!(pool.jobs.len(), 2);
}

#[test]
fn panicking_driver_seals_failed_and_runtime_survives() {
    let rt = Runtime::new(Config { pes: 1, ..Config::default() }).unwrap();
    let doomed = rt
        .submit_job(
            JobSpec::new("doomed")
                .kernel(synth_descriptor("synth_doom", 4))
                .driver(|_ctx| panic!("driver bug")),
        )
        .unwrap();
    assert!(doomed.wait().is_err(), "a panicked driver surfaces as Err");

    // the runtime is still serving: a fresh job runs to completion and
    // shutdown does not hang on the dead job's active count
    let ok = rt
        .submit_job(burst("survivor", "synth_srv", 4, 20, 1, None))
        .unwrap();
    assert_eq!(ok.wait().unwrap().series, vec![(20 * 4) as f64]);
    let pool = rt.shutdown();
    assert_eq!(pool.jobs.len(), 2, "the failed job still sealed a report");
    assert!(pool.job("doomed").is_some());
}

#[test]
fn metrics_snapshot_matches_sealed_report() {
    let rt = Runtime::new(Config { pes: 1, ..Config::default() }).unwrap();
    let h = rt
        .submit_job(burst("metered", "synth_m", 4, 120, 3, None))
        .unwrap();
    // handle stays usable for metrics while and after the job runs
    let job_id = h.job();
    assert_eq!(h.name(), "metered");
    // wait via polling to exercise the non-blocking probe
    while h.poll() == JobStatus::Running {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    assert_eq!(h.poll(), JobStatus::Done);
    let snap = h.metrics_snapshot();
    let report = h.wait().unwrap();
    assert_eq!(report.job, job_id);
    assert_eq!(snap.gpu_requests, report.gpu_requests);
    assert_eq!(snap.transfer_bytes, report.transfer_bytes);
    assert_eq!(snap.launches, report.launches);
    assert_eq!(snap.queued_requests, 0, "sealed job has nothing queued");
    assert_eq!(snap.outstanding, 0);
    rt.shutdown();
}
