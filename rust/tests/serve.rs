//! Serving front end (ISSUE 10) integration tests: admission
//! backpressure under every [`AdmissionPolicy`], QoS shed precedence,
//! deadline-aware combiner flushing, exact admission-ledger accounting
//! at the pool, and the metrics endpoint's socket round-trip.
//!
//! Gated jobs (a driver parked on an `AtomicBool`) pin the pool full
//! deterministically, so the admission verdicts here are exact rather
//! than timing-dependent; kernel-bearing jobs come from the `common`
//! burst helpers so deadline flushes have real combiner traffic to act
//! on.

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use gcharm::coordinator::{
    ChareId, CombinePolicy, Config, JobSpec, JobStatus, Runtime,
};
use gcharm::serve::{
    Admission, AdmissionPolicy, MetricsEndpoint, QosClass, ServeConfig,
    ServeFront,
};

use common::{synth_descriptor, BurstJob};

/// A kernel-free job whose driver parks until `release` flips (or a
/// cancel lands, sealing it `Cancelled`): holds a pool slot for as long
/// as the test wants the door full.
fn gated_spec(name: &str, release: Arc<AtomicBool>) -> JobSpec {
    JobSpec::new(name).driver(move |ctx| {
        let deadline = Instant::now() + Duration::from_secs(60);
        while !release.load(Ordering::SeqCst) {
            if ctx.cancelled() {
                return Err(anyhow::anyhow!("preempted"));
            }
            if Instant::now() > deadline {
                return Err(anyhow::anyhow!("gate never released"));
            }
            std::thread::sleep(Duration::from_micros(200));
        }
        Ok(Vec::new())
    })
}

/// A tight front: one pool slot, one slot per class.
fn tight(policy: AdmissionPolicy) -> ServeFront {
    ServeFront::new(ServeConfig {
        policy,
        class_depth: [1, 1, 1],
        pool_depth: 1,
        deadline: Some(0.05),
    })
    .unwrap()
}

/// Spin until a handle seals (bounded; the suite must not hang on a
/// broken seal).
fn await_seal(h: &gcharm::coordinator::JobHandle) -> JobStatus {
    let deadline = Instant::now() + Duration::from_secs(60);
    while h.poll() == JobStatus::Running {
        assert!(Instant::now() < deadline, "job never sealed");
        std::thread::sleep(Duration::from_micros(200));
    }
    h.poll()
}

#[test]
fn block_policy_backpressures_until_a_slot_frees() {
    let rt = Runtime::new(Config { pes: 1, ..Config::default() }).unwrap();
    let front = tight(AdmissionPolicy::Block);
    let gate_a = Arc::new(AtomicBool::new(false));
    let a = match front
        .offer(&rt, QosClass::Throughput, gated_spec("a", gate_a.clone()))
        .unwrap()
    {
        Admission::Admitted(h) => h,
        _ => panic!("empty pool must admit"),
    };

    // The second offer must block: full pool, Block policy. Run it on a
    // scoped thread and prove it is still parked after a real delay.
    let released = AtomicBool::new(false);
    let gate_b = Arc::new(AtomicBool::new(true)); // b runs through
    std::thread::scope(|s| {
        let offer = s.spawn(|| {
            let v = front
                .offer(&rt, QosClass::Throughput, gated_spec("b", gate_b))
                .unwrap();
            assert!(
                released.load(Ordering::SeqCst),
                "offer returned while the pool was still full"
            );
            v
        });
        std::thread::sleep(Duration::from_millis(50));
        let stats = front.stats();
        assert_eq!(stats.offered_total(), 2, "both offers recorded");
        assert_eq!(stats.admitted_total(), 1, "second offer still parked");
        // free the slot: a seals, the blocked offer admits
        released.store(true, Ordering::SeqCst);
        gate_a.store(true, Ordering::SeqCst);
        match offer.join().unwrap() {
            Admission::Admitted(h) => {
                assert_eq!(await_seal(&h), JobStatus::Done);
                h.wait().unwrap();
            }
            _ => panic!("Block never rejects or sheds"),
        }
    });
    assert_eq!(await_seal(&a), JobStatus::Done);
    a.wait().unwrap();
    front.drain();
    let stats = front.stats();
    assert!(stats.ledger_closes(), "{stats}");
    assert_eq!(stats.admitted_total(), 2);
    rt.shutdown();
}

#[test]
fn reject_policy_refuses_a_full_pool() {
    let rt = Runtime::new(Config { pes: 1, ..Config::default() }).unwrap();
    let front = tight(AdmissionPolicy::Reject);
    let gate = Arc::new(AtomicBool::new(false));
    let a = match front
        .offer(&rt, QosClass::Throughput, gated_spec("a", gate.clone()))
        .unwrap()
    {
        Admission::Admitted(h) => h,
        _ => panic!("empty pool must admit"),
    };
    let gate_b = Arc::new(AtomicBool::new(true));
    match front
        .offer(&rt, QosClass::Throughput, gated_spec("b", gate_b))
        .unwrap()
    {
        Admission::Rejected => {}
        _ => panic!("full pool under Reject must refuse"),
    }
    gate.store(true, Ordering::SeqCst);
    a.wait().unwrap();
    front.drain();
    let stats = front.stats();
    assert!(stats.ledger_closes(), "{stats}");
    assert_eq!(stats.rejected, [0, 1, 0]);

    // The pool-level copy of the ledger matches decision-for-decision.
    let pool = rt.shutdown();
    assert_eq!(pool.serve_offered, 2);
    assert_eq!(pool.serve_admitted, 1);
    assert_eq!(pool.serve_rejected, 1);
    assert_eq!(pool.serve_shed, 0);
}

#[test]
fn shed_preempts_strictly_lower_classes_only() {
    let rt = Runtime::new(Config { pes: 1, ..Config::default() }).unwrap();
    let front = ServeFront::new(ServeConfig {
        policy: AdmissionPolicy::Shed,
        class_depth: [1, 1, 1],
        pool_depth: 2,
        deadline: Some(0.05),
    })
    .unwrap();

    // Fill the pool: a latency tenant and a best-effort tenant.
    let gate_l = Arc::new(AtomicBool::new(false));
    let l = match front
        .offer(
            &rt,
            QosClass::LatencySensitive,
            gated_spec("lat", gate_l.clone()),
        )
        .unwrap()
    {
        Admission::Admitted(h) => h,
        _ => panic!("empty pool must admit"),
    };
    let gate_b = Arc::new(AtomicBool::new(false));
    let b = match front
        .offer(&rt, QosClass::BestEffort, gated_spec("be", gate_b))
        .unwrap()
    {
        Admission::Admitted(h) => h,
        _ => panic!("pool with room must admit"),
    };

    // QoS precedence: an incoming throughput offer preempts the
    // best-effort tenant — never the latency one.
    let gate_t = Arc::new(AtomicBool::new(true));
    let t = match front
        .offer(&rt, QosClass::Throughput, gated_spec("tp", gate_t))
        .unwrap()
    {
        Admission::Admitted(h) => h,
        _ => panic!("Shed with a lower-class victim must admit"),
    };
    assert_eq!(await_seal(&b), JobStatus::Cancelled);
    b.wait().unwrap();
    assert_eq!(l.poll(), JobStatus::Running, "latency tenant untouched");

    assert_eq!(await_seal(&t), JobStatus::Done);
    t.wait().unwrap();
    gate_l.store(true, Ordering::SeqCst);
    assert_eq!(await_seal(&l), JobStatus::Done);
    l.wait().unwrap();
    front.drain();

    let stats = front.stats();
    assert!(stats.ledger_closes(), "{stats}");
    // Preemption is not an offer verdict: all three offers admitted.
    assert_eq!(stats.admitted_total(), 3);
    assert_eq!(stats.shed_total(), 0);
    assert_eq!(stats.preempted[QosClass::BestEffort.index()], 1);
    let pool = rt.shutdown();
    assert_eq!(pool.serve_offered, 3);
    assert_eq!(pool.serve_admitted, 3);
}

#[test]
fn shed_refuses_when_nothing_lower_runs() {
    let rt = Runtime::new(Config { pes: 1, ..Config::default() }).unwrap();
    let front = tight(AdmissionPolicy::Shed);
    let gate = Arc::new(AtomicBool::new(false));
    let a = match front
        .offer(&rt, QosClass::BestEffort, gated_spec("a", gate.clone()))
        .unwrap()
    {
        Admission::Admitted(h) => h,
        _ => panic!("empty pool must admit"),
    };
    // Same class: best-effort never evicts best-effort — the offer
    // itself sheds.
    let gate_b = Arc::new(AtomicBool::new(true));
    match front
        .offer(&rt, QosClass::BestEffort, gated_spec("b", gate_b))
        .unwrap()
    {
        Admission::Shed => {}
        _ => panic!("no strictly-lower victim: the offer must shed"),
    }
    gate.store(true, Ordering::SeqCst);
    a.wait().unwrap();
    front.drain();
    let stats = front.stats();
    assert!(stats.ledger_closes(), "{stats}");
    assert_eq!(stats.shed, [0, 0, 1]);
    let pool = rt.shutdown();
    assert_eq!(pool.serve_offered, 2);
    assert_eq!(pool.serve_shed, 1);
}

/// Deadline-aware flushing: with static combining pinned far above the
/// burst size and the idle drain out of reach, `FlushReason::Deadline`
/// is the ONLY path that can move a latency-class job's requests — so
/// the job completing with its exact series proves the deadline fired
/// below `maxSize`, and the pool counter records it.
#[test]
fn deadline_flush_fires_below_max_size_for_latency_class() {
    let rt = Runtime::new(Config {
        pes: 1,
        combine: CombinePolicy::StaticEvery(100_000),
        idle_drain: 10.0,
        ..Config::default()
    })
    .unwrap();
    let front = ServeFront::new(ServeConfig {
        policy: AdmissionPolicy::Block,
        class_depth: [2, 2, 2],
        pool_depth: 4,
        deadline: Some(0.02),
    })
    .unwrap();
    let id = ChareId::new(3, 0);
    let job = BurstJob {
        name: "lat",
        desc: synth_descriptor("serve_deadline", 4),
        id,
        pe: 0,
        rows: 4,
        count: 12, // far below the family's combine cap
        rounds: 3,
        barrier: None,
    };
    let h = match front
        .offer(&rt, QosClass::LatencySensitive, job.spec())
        .unwrap()
    {
        Admission::Admitted(h) => h,
        _ => panic!("empty pool must admit"),
    };
    let report = h.wait().unwrap();
    assert_eq!(report.series, vec![(12 * 4) as f64; 3]);
    front.drain();
    let pool = rt.shutdown();
    assert!(
        pool.flush_deadline >= 1,
        "deadline flushes never fired: {pool}"
    );
}

/// A throughput-class tenant gets no deadline budget: the counter must
/// stay zero however its combiners flush.
#[test]
fn throughput_class_never_triggers_deadline_flushes() {
    let rt = Runtime::new(Config { pes: 1, ..Config::default() }).unwrap();
    let front = ServeFront::new(ServeConfig::default()).unwrap();
    let id = ChareId::new(3, 0);
    let job = BurstJob {
        name: "tp",
        desc: synth_descriptor("serve_no_deadline", 4),
        id,
        pe: 0,
        rows: 4,
        count: 20,
        rounds: 3,
        barrier: None,
    };
    let h = match front.offer(&rt, QosClass::Throughput, job.spec()).unwrap()
    {
        Admission::Admitted(h) => h,
        _ => panic!("empty pool must admit"),
    };
    let report = h.wait().unwrap();
    assert_eq!(report.series, vec![(20 * 4) as f64; 3]);
    front.drain();
    let pool = rt.shutdown();
    assert_eq!(
        pool.flush_deadline, 0,
        "throughput class armed a deadline: {pool}"
    );
}

#[test]
fn metrics_endpoint_round_trips_the_ledger_over_a_socket() {
    let rt = Runtime::new(Config { pes: 1, ..Config::default() }).unwrap();
    let front = ServeFront::new(ServeConfig::default()).unwrap();
    let ep = MetricsEndpoint::spawn(
        "127.0.0.1:0",
        rt.shared(),
        rt.snapshot_handle(),
        front.stats_arc(),
    )
    .unwrap();

    let gate = Arc::new(AtomicBool::new(false));
    let h = match front
        .offer(
            &rt,
            QosClass::LatencySensitive,
            gated_spec("scraped", gate.clone()),
        )
        .unwrap()
    {
        Admission::Admitted(h) => h,
        _ => panic!("empty pool must admit"),
    };

    // Live scrape: the admitted-but-running job shows in the serve
    // ledger section.
    let body = MetricsEndpoint::scrape(&ep.addr()).unwrap();
    assert!(
        body.contains("gcharm_serve_admitted{class=\"latency\"} 1"),
        "{body}"
    );
    assert!(body.contains("gcharm_pool_serve_offered 1"), "{body}");
    assert!(body.contains("gcharm_pool_serve_admitted 1"), "{body}");

    gate.store(true, Ordering::SeqCst);
    h.wait().unwrap();
    front.drain();

    // A second scrape over a fresh connection sees the completion.
    let body = MetricsEndpoint::scrape(&ep.addr()).unwrap();
    assert!(
        body.contains("gcharm_serve_completed{class=\"latency\"} 1"),
        "{body}"
    );
    drop(ep);
    rt.shutdown();
}
