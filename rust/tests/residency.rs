//! Reuse-graph residency properties (ISSUE 7), pinned at the public API:
//! farthest-next-use eviction, per-job namespacing of the reuse scorer,
//! prefetch that never evicts, and the policy toggle leaving the Lru
//! path's physics and metrics surface exactly as the seed shipped them.
//!
//! The scorer/pool/table unit matrices live next to their modules; these
//! tests exercise the composed behavior an application actually sees.

mod common;

use gcharm::coordinator::residency::UNSCORED;
use gcharm::coordinator::{
    ChareId, ChareTable, Config, JobSpec, KindStats, Msg, Report,
    ResidencyPolicy, ReuseScorer, Runtime,
};
use gcharm::runtime::DeviceMemory;

/// Job-namespaced residency key, as the coordinator derives it: the high
/// 16 bits carry the tenant, so per-key scores can never collide across
/// jobs.
fn job_key(job: u64, buf: u64) -> u64 {
    (job << 48) | buf
}

// ---------------------------------------------------------------------------
// Eviction order
// ---------------------------------------------------------------------------

#[test]
fn reuse_graph_evicts_farthest_next_use() {
    let mut mem = DeviceMemory::with_policy(2, ResidencyPolicy::ReuseGraph);
    mem.acquire_predicted(1, 10).unwrap();
    mem.acquire_predicted(2, 100).unwrap();
    // pool full; buffer 2's next use is forecast farthest away
    let (r, evicted) = mem.acquire_predicted(3, 50).unwrap();
    assert!(!r.is_hit());
    assert_eq!(evicted, Some(2), "must evict the farthest-next-use buffer");
    assert!(mem.peek(1).is_some(), "near-future buffer survives");
}

#[test]
fn unscored_buffers_evict_before_forecast_ones() {
    // A single-reference streaming key carries no forecast (UNSCORED =
    // u64::MAX) and must be the first casualty — this is exactly what
    // shields a hot set from a co-tenant's scan.
    let mut mem = DeviceMemory::with_policy(2, ResidencyPolicy::ReuseGraph);
    mem.acquire_predicted(1, 40).unwrap(); // hot, forecast soon
    mem.acquire_predicted(2, UNSCORED).unwrap(); // scan, never seen again
    let (_, evicted) = mem.acquire_predicted(3, UNSCORED).unwrap();
    assert_eq!(evicted, Some(2), "scan traffic must displace itself");
    assert!(mem.peek(1).is_some());
}

#[test]
fn lru_policy_ignores_predictions() {
    // Under `ResidencyPolicy::Lru` the forecast argument is dead weight:
    // eviction is least-recently-touched, exactly the seed behavior.
    let mut mem = DeviceMemory::with_policy(2, ResidencyPolicy::Lru);
    mem.acquire_predicted(1, 10).unwrap();
    mem.acquire_predicted(2, u64::MAX).unwrap();
    mem.acquire_predicted(1, 10).unwrap(); // touch 1: 2 is now LRU
    let (_, evicted) = mem.acquire_predicted(3, 50).unwrap();
    assert_eq!(evicted, Some(2), "Lru must evict by recency, not forecast");
}

// ---------------------------------------------------------------------------
// Per-job namespacing
// ---------------------------------------------------------------------------

#[test]
fn scorer_scores_are_namespaced_per_job() {
    let mut s = ReuseScorer::new();
    // job 1 references its buffer 5 on a steady cadence -> forecast
    for _ in 0..4 {
        s.note(job_key(1, 5));
    }
    // job 2's buffer 5 is a different key entirely: one cold reference
    assert_eq!(s.note(job_key(2, 5)), UNSCORED);
    assert_ne!(s.predicted_next(job_key(1, 5)), UNSCORED);

    // tearing down job 2 leaves job 1's graph untouched
    s.forget_job(2);
    assert_ne!(s.predicted_next(job_key(1, 5)), UNSCORED);
    assert_eq!(s.predicted_next(job_key(2, 5)), UNSCORED);
}

#[test]
fn hot_set_survives_co_tenant_scan_in_one_table() {
    // Two tenants share one 4-slot table. Job 1 keeps one hot buffer
    // with a forecast; job 2 streams 32 single-use buffers through. The
    // hot buffer must still be resident when the scan is done.
    let mut table =
        ChareTable::with_policy(4, 8, ResidencyPolicy::ReuseGraph);
    let hot = job_key(1, 0);
    let data = vec![1.0f32; 8];
    table.stage_pinned_predicted(hot, &data, 8).unwrap();
    table.release(hot);

    for b in 0..32u64 {
        let key = job_key(2, b);
        table.stage_pinned_predicted(key, &data, UNSCORED).unwrap();
        table.release(key);
    }
    assert!(
        table.resident_keys().contains(&hot),
        "co-tenant scan flushed the hot set: {:?}",
        table.resident_keys()
    );
}

// ---------------------------------------------------------------------------
// Prefetch
// ---------------------------------------------------------------------------

#[test]
fn prefetch_only_fills_free_slots_and_never_evicts() {
    let mut table =
        ChareTable::with_policy(2, 4, ResidencyPolicy::ReuseGraph);
    let data = vec![2.0f32; 4];
    // Fill the pool, then push buffer 1 out so it lands in the victim
    // cache (prefetch restages only data it still holds host-side).
    table.stage_pinned_predicted(1, &data, 50).unwrap();
    table.release(1);
    table.stage_pinned_predicted(2, &data, 10).unwrap();
    table.release(2);
    table.stage_pinned_predicted(3, &data, 20).unwrap();
    table.release(3);
    assert!(table.prefetchable(1), "evicted buffer must be restageable");

    // pool full: a scored-hotter resident must NOT be displaced
    assert_eq!(table.prefetch(1, 5), None, "prefetch must never evict");
    assert!(table.resident_keys().contains(&2));
    assert!(table.resident_keys().contains(&3));

    // free a slot: now the restage succeeds and costs real bytes
    table.invalidate(3);
    let bytes = table.prefetch(1, 5).expect("free slot available");
    assert_eq!(bytes, 16, "4 floats restaged");
    assert_eq!(table.prefetch_transferred_bytes(), 16);

    // the demand arrives: a hit, attributed to the prefetcher
    let staged = table.stage_pinned_predicted(1, &data, 60).unwrap();
    assert_eq!(staged.bytes, 0, "prefetched buffer hits without a copy");
    table.release(1);
    assert_eq!(table.prefetch_hits(), 1);
    assert_eq!(table.prefetch_wasted(), 0);
}

#[test]
fn prefetched_buffer_evicted_before_demand_counts_wasted() {
    let mut table =
        ChareTable::with_policy(2, 4, ResidencyPolicy::ReuseGraph);
    let data = vec![3.0f32; 4];
    table.stage_pinned_predicted(1, &data, 100).unwrap();
    table.release(1);
    table.stage_pinned_predicted(2, &data, 10).unwrap();
    table.release(2);
    table.stage_pinned_predicted(3, &data, 20).unwrap();
    table.release(3);
    // 1 was evicted; restage it speculatively into a freed slot
    table.invalidate(2);
    table.prefetch(1, 90).expect("restaged");
    // demand for a new buffer arrives first; 1 forecasts farthest (90)
    // and is evicted unused
    table.stage_pinned_predicted(4, &data, 30).unwrap();
    table.release(4);
    assert_eq!(table.prefetch_wasted(), 1);
    assert_eq!(table.prefetch_hits(), 0);
}

// ---------------------------------------------------------------------------
// End to end: the policy knob on a real reuse workload
// ---------------------------------------------------------------------------

/// A reuse-heavy burst job driven through the public API: `count`
/// requests per round cycling `nbuf` buffer ids through the chare
/// tables, reduction exact in f64 (see `common::ReuseBurster`).
fn reuse_spec(rounds: usize, count: usize, nbuf: usize) -> JobSpec {
    let id = ChareId::new(9, 0);
    let rows = 4;
    JobSpec::new("reuse_burst")
        .kernel(common::reuse_descriptor("resprop", rows))
        .chare(
            id,
            0,
            Box::new(common::ReuseBurster {
                id,
                rows,
                count,
                nbuf,
                pending: 0,
                sum: 0.0,
            }),
        )
        .driver(move |ctx| {
            let kind = ctx.kinds()[0];
            let mut series = Vec::with_capacity(rounds);
            for _ in 0..rounds {
                ctx.send(id, Msg::new(common::METHOD_GO, kind));
                series.push(ctx.await_reduction(1)?);
                ctx.await_quiescence();
            }
            Ok(series)
        })
}

/// The policy knob moves bytes, never values: under a starved 4-slot
/// table (nbuf = 8 forces real eviction churn) both policies must
/// produce the exact analytic reduction every round. The Lru run must
/// keep the seed's metrics surface — zero prefetch activity — and the
/// reuse-graph run must obey the prefetch partition contract.
#[test]
fn policies_agree_exactly_on_a_reuse_workload() {
    let run = |policy: ResidencyPolicy| -> (Vec<f64>, Report) {
        let rt = Runtime::new(Config {
            pes: 2,
            table_slots: 4,
            residency: policy,
            ..Config::default()
        })
        .expect("runtime");
        let series = rt
            .submit_job(reuse_spec(3, 64, 8))
            .expect("submit")
            .wait()
            .expect("job ran")
            .series;
        (series, rt.shutdown())
    };
    let (lru_series, lru) = run(ResidencyPolicy::Lru);
    let (reuse_series, reuse) = run(ResidencyPolicy::ReuseGraph);

    // Exact physics: 64 requests/round, each b in 0..8 appearing 8
    // times, rows = 4: sum = 8 * 4 * (1 + ... + 8) = 1152.
    assert_eq!(lru_series, vec![1152.0; 3], "Lru physics drifted");
    assert_eq!(reuse_series, vec![1152.0; 3], "ReuseGraph physics drifted");

    // Lru is the seed path: the new machinery must be fully disengaged.
    assert_eq!(lru.prefetch_hits, 0);
    assert_eq!(lru.prefetch_wasted, 0);
    assert_eq!(lru.prefetch_bytes, 0);

    // Whatever the reuse-graph run prefetched must obey the partition
    // contract: pool totals == kind sums, hits bounded by table hits.
    let ksum = |f: fn(&KindStats) -> u64| -> u64 {
        reuse.kind_stats.iter().map(f).sum()
    };
    assert_eq!(reuse.prefetch_hits, ksum(|k| k.prefetch_hits));
    assert_eq!(reuse.prefetch_wasted, ksum(|k| k.prefetch_wasted));
    for k in &reuse.kind_stats {
        assert!(
            k.prefetch_hits <= k.table_hits,
            "{}: prefetch hits exceed table hits",
            k.name
        );
    }
}
