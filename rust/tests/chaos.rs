//! Chaos-harness regression corpus (`cargo test --features chaos`).
//!
//! Each seed is a complete fault schedule ([`gcharm::chaos::Schedule`]):
//! the contiguous corpus 0..=15 covers every fault theme — scripted
//! cancels at three quiescence depths, panicking drivers, steal storms,
//! flush-timing jitter, live registration and rejected submissions,
//! cache pressure (a starved chare table fought over by a hot tenant and
//! an adversarial streaming scan), launch-mode flips that jitter the
//! persistent work rings mid-job, node faults (the job run SPMD on
//! a two-node loopback fabric with delayed / reordered / dropped frames
//! and a graceful mid-run peer departure), and overload (saturating
//! best-effort bursts against a tiny `serve::ServeFront` pool with Shed
//! admission, the ledger closing exactly) — twice each. A failing seed
//! replays bit-identically with
//! `gcharm chaos --seed N` (the whole schedule, including its event
//! trace, is a pure function of the seed).
//!
//! Also pinned here: the two bugs the harness's first sweep flushed out
//! (a combiner residual-debt stall after a forced flush — unit-pinned in
//! `coordinator::combiner` — and a job-id leak on rejected submissions,
//! pinned end-to-end below).

use gcharm::chaos::{
    accounting_violations, job_spec_for, run_schedule, theme_name,
    FamilySpec, Fault, JobPlan, Schedule,
};
use gcharm::coordinator::{Config, JobReport, PoolReport, Runtime};

/// The regression corpus: every theme twice (seed % 8 cycles them).
const CORPUS: std::ops::Range<u64> = 0..16;

#[test]
fn seed_corpus_holds_all_invariants() {
    for seed in CORPUS {
        let r = run_schedule(seed).expect("harness ran");
        assert!(
            r.ok(),
            "seed {seed} ({}) violated invariants:\n{r}",
            theme_name(seed)
        );
    }
}

#[test]
fn corpus_covers_every_fault_theme_twice() {
    let mut counts = std::collections::HashMap::new();
    for seed in CORPUS {
        *counts.entry(theme_name(seed)).or_insert(0usize) += 1;
    }
    for theme in [
        "cancel",
        "driver-panic",
        "steal-storm",
        "live-registration",
        "cache-pressure",
        "launch-flip",
        "node-fault",
        "overload",
    ] {
        assert_eq!(counts.get(theme), Some(&2), "theme {theme} undercovered");
    }
}

/// Replay determinism: the event trace is a pure function of the seed,
/// so a failure anywhere reproduces exactly from its seed number.
#[test]
fn same_seed_replays_an_identical_trace() {
    // one seed per theme; two full runs each (fresh runtime every time)
    for seed in 0..8u64 {
        let a = run_schedule(seed).expect("first run");
        let b = run_schedule(seed).expect("replay");
        assert!(a.ok(), "seed {seed}:\n{a}");
        assert_eq!(
            a.trace, b.trace,
            "seed {seed} ({}) replayed a different trace",
            theme_name(seed)
        );
        assert_eq!(Schedule::from_seed(seed), Schedule::from_seed(seed));
    }
}

/// The invariant checker must itself be falsifiable: a report whose
/// per-job sums do not reproduce the pool totals has to be flagged.
/// (The checker's full unit matrix lives in `chaos::invariants`.)
#[test]
fn deliberately_broken_accounting_is_detected() {
    let mut pool = PoolReport::default();
    pool.jobs.push(JobReport { gpu_requests: 7, ..Default::default() });
    // the job claims 7 requests the pool never saw: the checker must bite
    let v = accounting_violations(&pool);
    assert!(
        v.iter().any(|s| s.contains("gpu_requests")),
        "checker passed a corrupted report: {v:?}"
    );
}

/// Harness-found bug, pinned end-to-end: a rejected `submit_job`
/// (incompatible re-registration) used to leak the job id it had
/// reserved from the 16-bit recycling pool. With the fix, the id a
/// sealed job freed survives a rejected submission and is handed to the
/// next accepted one.
#[test]
fn rejected_submission_returns_its_job_id_to_the_pool() {
    let rt = Runtime::new(Config { pes: 1, ..Config::default() }).unwrap();
    let spec = |name: &str, family: &str, rows: usize| {
        let fam = FamilySpec {
            name: family.to_string(),
            rows,
            reuse: false,
            static_period: None,
            cpu_fallback: false,
            persistent: false,
        };
        let plan = JobPlan {
            name: name.to_string(),
            family: 0,
            count: 10,
            rounds: 1,
            chares: 1,
            nbuf: 4,
            fill: 1.0,
            fault: Fault::None,
        };
        job_spec_for(&plan, &fam)
    };

    let h1 = rt.submit_job(spec("first", "recycle_fam", 4)).unwrap();
    let id1 = h1.job();
    h1.wait().unwrap(); // seals: id1 returns to the free pool

    // incompatible shape for the same family: rejected at submit — and
    // the id it popped must flow back
    let err = rt.submit_job(spec("bad", "recycle_fam", 8)).unwrap_err();
    assert!(err.to_string().contains("bad"), "{err}");

    let h2 = rt.submit_job(spec("second", "recycle_fam2", 4)).unwrap();
    assert_eq!(
        h2.job(),
        id1,
        "rejected submission leaked job id {id1} from the recycling pool"
    );
    h2.wait().unwrap();
    rt.shutdown();
}

/// Seeds 5 and 13 are the corpus's launch-flip schedules: every family
/// pinned persistent, two mid-job injections that shrink the work rings
/// to 1-4 slots and alternate the forced mode Persistent -> PerBatch.
/// Each run must stay exact for every tenant, fire both flips, and seal
/// a report whose `persistent_batches + per_batch_launches == launches`
/// partition holds (checked by `accounting_violations` inside the
/// harness) — with shutdown terminating under the watchdog even when a
/// ring still holds descriptors at the flip.
#[test]
fn launch_flip_keeps_tenants_exact_and_partitions_launches() {
    for seed in [5u64, 13] {
        assert_eq!(theme_name(seed), "launch-flip");
        let s = Schedule::from_seed(seed);
        assert!(
            s.families.iter().all(|f| f.persistent),
            "seed {seed}: theme pins families persistent"
        );
        let r = run_schedule(seed).expect("harness ran");
        assert!(r.ok(), "seed {seed}:\n{r}");
        let flips = r
            .trace
            .iter()
            .filter(|l| l.contains("inject launch-mode-flip"))
            .count();
        assert_eq!(flips, 2, "seed {seed}: both flips must fire:\n{r}");
        // every tenant is fault-free under this theme, so every series
        // must verify exactly across the mode changes
        let exact = r
            .trace
            .iter()
            .filter(|l| l.contains("series-exact"))
            .count();
        assert_eq!(
            exact,
            s.jobs.len(),
            "seed {seed}: {exact} exact series for {} tenants:\n{r}",
            s.jobs.len()
        );
    }
}

/// Seeds 4 and 12 are the corpus's cache-pressure schedules: one device,
/// one shared reuse family, a chare table of 6-11 slots, job 0 cycling a
/// hot set that fits, and every co-tenant streaming a scan wider than the
/// whole table once per round. The run must stay exact for every tenant
/// (the scan's own physics included) and hold the prefetch accounting
/// invariants under real eviction churn; pinned-slot eviction would trip
/// the pool's debug assertions, which are live in this profile.
#[test]
fn cache_pressure_keeps_every_tenant_exact() {
    for seed in [4u64, 12] {
        assert_eq!(theme_name(seed), "cache-pressure");
        let s = Schedule::from_seed(seed);
        let slots = s.table_slots.expect("theme shrinks the table");
        assert!(
            s.jobs[1..].iter().all(|j| j.nbuf > slots),
            "seed {seed}: scans must overflow the table"
        );
        let r = run_schedule(seed).expect("harness ran");
        assert!(r.ok(), "seed {seed}:\n{r}");
        assert!(
            r.trace.iter().any(|l| l.contains("theme=cache-pressure")),
            "seed {seed}: trace lost its theme header:\n{r}"
        );
        // every tenant is fault-free under this theme, so every series
        // must verify exactly — the hot set survived the scans
        let exact = r
            .trace
            .iter()
            .filter(|l| l.contains("series-exact"))
            .count();
        assert_eq!(
            exact,
            s.jobs.len(),
            "seed {seed}: {exact} exact series for {} tenants:\n{r}",
            s.jobs.len()
        );
    }
}

/// Seeds 6 and 14 are the corpus's node-fault schedules: the single
/// clean job runs SPMD on a two-node loopback fabric whose links delay,
/// reorder, and drop (heartbeats only) frames, with node 1 optionally
/// leaving gracefully mid-run. The root's cross-node reduction series
/// must equal the exact degraded-cluster physics, and the per-node
/// reports must balance the cross-node steal/request/byte conservation
/// ledger in exact mode (`cluster_violations` inside the harness).
#[test]
fn node_fault_keeps_the_degraded_series_exact_and_books_balanced() {
    for seed in [6u64, 14] {
        assert_eq!(theme_name(seed), "node-fault");
        let s = Schedule::from_seed(seed);
        let c = s.cluster.expect("theme runs on a cluster");
        assert_eq!(c.nodes, 2);
        let r = run_schedule(seed).expect("harness ran");
        assert!(r.ok(), "seed {seed}:\n{r}");
        assert!(
            r.trace.iter().any(|l| l.contains("cluster: root series exact")),
            "seed {seed}: degraded series never verified:\n{r}"
        );
        assert!(
            r.trace.iter().any(|l| l.contains("cluster accounting: clean")),
            "seed {seed}: conservation ledger never checked:\n{r}"
        );
    }
}

/// Seeds 7 and 15 are the corpus's overload schedules: one device, one
/// healthy latency-class tenant admitted through a `serve::ServeFront`
/// (Shed policy, pool depth 2, best-effort depth 1), then a saturating
/// burst of best-effort offers. The admission ledger must close exactly
/// — the front end's own counters, the pool-level copy (audited by
/// `accounting_violations` inside the harness), and the two agreeing —
/// and the latency co-tenant's reduction series must stay exact physics
/// under the burst. The admitted/shed split within the burst races job
/// seals and is deliberately NOT asserted; only the closure is.
#[test]
fn overload_closes_the_ledger_and_keeps_latency_exact() {
    for seed in [7u64, 15] {
        assert_eq!(theme_name(seed), "overload");
        let s = Schedule::from_seed(seed);
        let o = s.overload.expect("theme plans a burst");
        assert!(o.burst > o.pool_depth, "seed {seed}: burst must saturate");
        let r = run_schedule(seed).expect("harness ran");
        assert!(r.ok(), "seed {seed}:\n{r}");
        assert!(
            r.trace.iter().any(|l| l.contains("latency tenant admitted")),
            "seed {seed}: latency tenant never admitted:\n{r}"
        );
        assert!(
            r.trace.iter().any(|l| l.contains("latency series exact")),
            "seed {seed}: latency physics never verified:\n{r}"
        );
        assert!(
            r.trace.iter().any(|l| l.contains("front ledger closes")),
            "seed {seed}: admission ledger never verified:\n{r}"
        );
        assert!(
            r.trace.iter().any(|l| l.contains("accounting: clean")),
            "seed {seed}: pool-level ledger never checked:\n{r}"
        );
    }
}

/// Seed 0 is a cancel-theme schedule: its job 0 is the healthy co-tenant
/// whose exact physics must survive while its neighbours are cancelled.
/// Both verdicts must actually appear in the trace (a corpus that never
/// verifies a cancel verifies nothing).
#[test]
fn cancelled_seed_leaves_healthy_tenant_exact() {
    let s = Schedule::from_seed(0);
    assert_eq!(theme_name(0), "cancel");
    assert!(
        s.jobs.iter().skip(1).any(|j| !matches!(j.fault, Fault::None)),
        "cancel theme must actually cancel someone"
    );
    let r = run_schedule(0).expect("harness ran");
    assert!(r.ok(), "{r}");
    assert!(
        r.trace.iter().any(|l| l.contains("series-exact")),
        "healthy tenant's exact physics never checked:\n{r}"
    );
    assert!(
        r.trace.iter().any(|l| l.contains("cancelled-clean")),
        "no cancel was verified:\n{r}"
    );
}
