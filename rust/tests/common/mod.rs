//! Shared helpers for the multi-tenant runtime integration tests: a
//! synthetic GPU-only kernel family and a bursting chare whose per-round
//! reduction (`count * rows`, all-ones tiles) is exact in f64 regardless
//! of combining, splitting, or arrival order — the property the
//! equivalence and accounting tests lean on.
#![allow(dead_code)]

use std::sync::{Arc, Barrier};

use gcharm::coordinator::{
    Chare, ChareId, Ctx, JobSpec, KernelDescriptor, KernelKindId, Msg, Tile,
    WorkDraft, WrResult, METHOD_RESULT,
};
use gcharm::runtime::kernel::{TileArgSpec, TileKernel};
use gcharm::runtime::KernelResources;

pub const METHOD_GO: u32 = 1;

/// Per-slot kernel: sum of the tile entries.
pub fn sum_slot(args: &[&[f32]], _c: &[f32]) -> Vec<f32> {
    vec![args[0].iter().sum()]
}

/// A synthetic GPU-only family: `rows x 1` tile, 1x1 output, occupancy
/// cap 104 on the modeled device.
pub fn synth_descriptor(name: &str, rows: usize) -> KernelDescriptor {
    KernelDescriptor {
        kernel: Arc::new(TileKernel {
            name: Arc::from(name),
            args: vec![TileArgSpec { name: "tile", rows, width: 1, pad: 0.0 }],
            constant: Arc::new(Vec::new()),
            out_rows: 1,
            out_width: 1,
            resources: KernelResources {
                threads_per_block: 128,
                regs_per_thread: 64,
                smem_per_block: 4096,
            },
            items_per_slot: rows as u64,
            reuse_arg: None,
            gather_name: None,
            entry_arg: None,
            slot_fn: sum_slot,
        }),
        combine: None,
        sort_by_slot: false,
        cpu_fallback: false,
        launch_mode: None,
    }
}

/// [`synth_descriptor`] with the residency path wired up: the tile is a
/// reuse arg staged through the chare tables, with a gather variant and
/// slot-sorted coalescing (the combination the apps use).
pub fn reuse_descriptor(name: &str, rows: usize) -> KernelDescriptor {
    let mut desc = synth_descriptor(name, rows);
    let k = Arc::get_mut(&mut desc.kernel).expect("fresh kernel");
    k.reuse_arg = Some(0);
    k.gather_name = Some(Arc::from(format!("{name}_gather")));
    desc.sort_by_slot = true;
    desc
}

/// A chare that bursts `count` all-ones requests of the kind carried by
/// each GO message and contributes the summed outputs once every result
/// returned.
pub struct Burster {
    pub id: ChareId,
    pub rows: usize,
    pub count: usize,
    pub pending: usize,
    pub sum: f64,
}

impl Chare for Burster {
    fn receive(&mut self, msg: Msg, ctx: &mut Ctx) {
        match msg.method {
            METHOD_GO => {
                let kind: KernelKindId = msg.take();
                self.pending = self.count;
                self.sum = 0.0;
                for i in 0..self.count {
                    ctx.submit(WorkDraft {
                        chare: self.id,
                        kind,
                        buffer: None,
                        data_items: self.rows,
                        tag: i as u64,
                        payload: Tile::new(vec![vec![1.0; self.rows]]),
                    })
                    .expect("registered tile shape");
                }
            }
            METHOD_RESULT => {
                let r: WrResult = msg.take();
                self.sum += r.out[0] as f64;
                self.pending -= 1;
                if self.pending == 0 {
                    ctx.contribute(self.sum);
                }
            }
            other => panic!("unknown method {other}"),
        }
    }
}

/// Residency-path burster: cycles `nbuf` reuse-buffer ids, each carrying
/// id-determined integer tile values (repeated ids carry identical data,
/// so staging a stale resident copy would be caught by the exact
/// reduction). Per-round sum: `sum_i rows * (1 + i % nbuf)` — exact in
/// f64 in any arrival order.
pub struct ReuseBurster {
    pub id: ChareId,
    pub rows: usize,
    pub count: usize,
    pub nbuf: usize,
    pub pending: usize,
    pub sum: f64,
}

impl Chare for ReuseBurster {
    fn receive(&mut self, msg: Msg, ctx: &mut Ctx) {
        match msg.method {
            METHOD_GO => {
                let kind: KernelKindId = msg.take();
                self.pending = self.count;
                self.sum = 0.0;
                for i in 0..self.count {
                    let b = (i % self.nbuf) as u64;
                    ctx.submit(WorkDraft {
                        chare: self.id,
                        kind,
                        buffer: Some(b),
                        data_items: self.rows,
                        tag: i as u64,
                        payload: Tile::new(vec![vec![
                            1.0 + b as f32;
                            self.rows
                        ]]),
                    })
                    .expect("registered tile shape");
                }
            }
            METHOD_RESULT => {
                let r: WrResult = msg.take();
                self.sum += r.out[0] as f64;
                self.pending -= 1;
                if self.pending == 0 {
                    ctx.contribute(self.sum);
                }
            }
            other => panic!("unknown method {other}"),
        }
    }
}

/// One burst job: `rounds` rounds of `count` requests from a single
/// chare, optionally gated on a barrier so co-tenant bursts overlap in
/// the shared combiners. Series = the per-round sums
/// (`count * rows` each).
pub struct BurstJob {
    pub name: &'static str,
    pub desc: KernelDescriptor,
    pub id: ChareId,
    pub pe: usize,
    pub rows: usize,
    pub count: usize,
    pub rounds: usize,
    pub barrier: Option<Arc<Barrier>>,
}

impl BurstJob {
    pub fn spec(self) -> JobSpec {
        let BurstJob { name, desc, id, pe, rows, count, rounds, barrier } =
            self;
        JobSpec::new(name)
            .kernel(desc)
            .chare(
                id,
                pe,
                Box::new(Burster { id, rows, count, pending: 0, sum: 0.0 }),
            )
            .driver(move |ctx| {
                let kind = ctx.kinds()[0];
                let mut series = Vec::with_capacity(rounds);
                for _ in 0..rounds {
                    if let Some(b) = &barrier {
                        b.wait();
                    }
                    ctx.send(id, Msg::new(METHOD_GO, kind));
                    series.push(ctx.await_reduction(1)?);
                    ctx.await_quiescence();
                }
                Ok(series)
            })
    }
}
