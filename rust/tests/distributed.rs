//! Multi-node runtime (ISSUE 9): the loopback cluster reproduces the
//! in-process `Runtime`, multi-node runs are deterministic, every
//! `WirePayload` shape crosses the wire intact, and a real TCP mesh
//! round-trips on 127.0.0.1.
//!
//! Invariants covered:
//!   - `Cluster::loopback` with nodes=1 is bitwise-identical to the
//!     plain in-process `Runtime` on the same spec: same reduction
//!     series (compared by bits), same request/item/byte accounting,
//!     and zero wire traffic;
//!   - 2- and 4-node loopback runs are deterministic across repeated
//!     runs: the root's cross-node reduction series equals the exact
//!     integer physics (`nodes * count * rows` per round) both times,
//!     only the root owns a series, and the cross-node steal /
//!     request / byte ledgers balance over the cluster;
//!   - every [`WirePayload`] variant delivered via
//!     `ClusterHandle::send_remote` arrives intact (an echo chare
//!     folds a payload-determined checksum into an exact reduction);
//!   - two [`Tcp`] endpoints over real 127.0.0.1 sockets complete a
//!     cluster job with the same exact series and balanced books.

mod common;

use std::net::TcpListener;
use std::sync::Arc;
use std::thread;

use common::{synth_descriptor, Burster, METHOD_GO};
use gcharm::coordinator::{
    Chare, ChareId, Config, Ctx, JobSpec, Msg, PoolReport, Runtime,
};
use gcharm::net::{
    Cluster, ClusterHandle, ClusterNode, NetConfig, NodeId, NodeReport, Tcp,
    Transport, WirePayload,
};

const BURST_ID: ChareId = ChareId { collection: 7, index: 0 };

fn cfg(pes: usize) -> Config {
    Config { pes, ..Config::default() }
}

/// SPMD cluster job: every node runs one [`Burster`] chare for `rounds`
/// rounds and folds each round's local reduction through the cluster
/// tree. Only the root's driver collects the (cluster-total) series.
fn cluster_burst_spec(
    family: &str,
    rows: usize,
    count: usize,
    rounds: usize,
    h: ClusterHandle,
) -> JobSpec {
    let id = BURST_ID;
    JobSpec::new("dist-burst")
        .kernel(synth_descriptor(family, rows))
        .chare(
            id,
            0,
            Box::new(Burster { id, rows, count, pending: 0, sum: 0.0 }),
        )
        .driver(move |ctx| {
            let kind = ctx.kinds()[0];
            let mut series = Vec::with_capacity(rounds);
            for r in 0..rounds {
                ctx.send(id, Msg::new(METHOD_GO, kind));
                let local = ctx.await_reduction(1)?;
                ctx.await_quiescence();
                if let Some((n, total)) = h.reduce(r as u32, 1, local) {
                    assert_eq!(
                        n,
                        h.nodes() as u64,
                        "every node contributes every round"
                    );
                    series.push(total);
                }
            }
            Ok(series)
        })
}

/// The cross-node conservation ledger, hand-rolled so the tier-1 suite
/// checks it without the chaos feature (the chaos checker's
/// `cluster_violations` audits the same sums under fault injection).
fn assert_cluster_books_balance(reports: &[NodeReport]) {
    let sum = |f: fn(&PoolReport) -> u64| -> u64 {
        reports.iter().map(|r| f(&r.pool)).sum()
    };
    assert_eq!(
        sum(|p| p.remote_steals_out) + sum(|p| p.remote_stale_batches),
        sum(|p| p.remote_steals_in) + sum(|p| p.remote_requeues),
        "every shipped batch must resolve exactly once"
    );
    assert_eq!(
        sum(|p| p.remote_requests_out) + sum(|p| p.remote_stale_results),
        sum(|p| p.remote_requests_in) + sum(|p| p.remote_requeued_requests),
        "every shipped request must resolve exactly once"
    );
    // graceful shutdown, nothing deliberately dropped: exact balance
    assert_eq!(
        sum(|p| p.wire_bytes_out),
        sum(|p| p.wire_bytes_in),
        "graceful runs put exactly as many bytes on the wire as came off"
    );
    for r in reports {
        let per_job: u64 =
            r.pool.jobs.iter().map(|j| j.remote_requests).sum();
        assert_eq!(
            per_job, r.pool.remote_requests_out,
            "{}: per-job remote requests must sum to the node total",
            r.node
        );
    }
}

#[test]
fn single_node_loopback_is_bitwise_identical_to_in_process() {
    let rows = 4;
    let count = 60;
    let rounds = 3;

    // plain in-process runtime
    let rt = Runtime::new(cfg(2)).unwrap();
    let spec = cluster_burst_spec(
        "dist_solo",
        rows,
        count,
        rounds,
        ClusterHandle::solo(),
    );
    let plain = rt.submit_job(spec).unwrap().wait().unwrap();
    let plain_pool = rt.shutdown();

    // the same spec on a 1-node loopback cluster
    let reports = Cluster::loopback(
        1,
        cfg(2),
        NetConfig::default(),
        move |_, h| cluster_burst_spec("dist_solo", rows, count, rounds, h),
    )
    .unwrap();
    assert_eq!(reports.len(), 1);
    let rep = &reports[0];

    // series bitwise-identical (exact integers, but compare the bits)
    assert_eq!(plain.series.len(), rep.series.len());
    for (a, b) in plain.series.iter().zip(&rep.series) {
        assert_eq!(a.to_bits(), b.to_bits(), "series must match bitwise");
    }
    assert_eq!(plain.series, vec![(count * rows) as f64; rounds]);

    // identical work accounting (launch counts are timing-dependent
    // via the idle flusher; requests/items/bytes are not)
    let clustered = rep.pool.job("dist-burst").unwrap();
    assert_eq!(plain.gpu_requests, clustered.gpu_requests);
    assert_eq!(plain.cpu_requests, clustered.cpu_requests);
    assert_eq!(plain.gpu_items, clustered.gpu_items);
    assert_eq!(plain.cpu_items, clustered.cpu_items);
    assert_eq!(plain.transfer_bytes, clustered.transfer_bytes);
    assert_eq!(plain_pool.gpu_requests, rep.pool.gpu_requests);
    assert_eq!(plain_pool.gpu_items, rep.pool.gpu_items);
    assert_eq!(plain_pool.transfer_bytes, rep.pool.transfer_bytes);

    // a solo node never touches the wire
    assert_eq!(rep.pool.wire_bytes_out, 0);
    assert_eq!(rep.pool.wire_bytes_in, 0);
    assert_eq!(rep.pool.remote_steals_out, 0);
    assert_eq!(rep.pool.remote_steals_in, 0);
    assert!(rep.peer_summaries.is_empty());
}

fn run_loopback(nodes: usize, count: usize, rounds: usize) -> Vec<NodeReport> {
    Cluster::loopback(nodes, cfg(1), NetConfig::default(), move |_, h| {
        cluster_burst_spec("dist_multi", 4, count, rounds, h)
    })
    .unwrap()
}

#[test]
fn multi_node_loopback_is_deterministic_with_exact_series() {
    for &(nodes, count) in &[(2usize, 40usize), (4, 25)] {
        let rounds = 3;
        let first = run_loopback(nodes, count, rounds);
        let second = run_loopback(nodes, count, rounds);

        let want = vec![(nodes * count * 4) as f64; rounds];
        for run in [&first, &second] {
            assert_eq!(run.len(), nodes);
            assert_eq!(
                run[0].series, want,
                "{nodes}-node root series must be the exact cluster physics"
            );
            for rep in &run[1..] {
                assert!(
                    rep.series.is_empty(),
                    "only the root owns the cluster series"
                );
            }
            assert_eq!(run[0].peer_summaries.len(), nodes - 1);
            assert_cluster_books_balance(run);
        }
        // run-to-run determinism, bitwise
        for (a, b) in first[0].series.iter().zip(&second[0].series) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}

const ECHO_ID: ChareId = ChareId { collection: 9, index: 0 };
const ECHO_KINDS: u32 = 6;

/// Receives one message per [`WirePayload`] shape, verifies the exact
/// content, and contributes a payload-determined checksum once all
/// shapes arrived. Methods 10..16 index the shapes.
struct EchoChare {
    got: u32,
    sum: f64,
}

impl Chare for EchoChare {
    fn receive(&mut self, msg: Msg, ctx: &mut Ctx) {
        let method = msg.method;
        let p: WirePayload = msg.take();
        let add = match (method, &p) {
            (10, WirePayload::Empty) => 1.0,
            (11, WirePayload::U32(x)) => {
                assert_eq!(*x, 7);
                7.0
            }
            (12, WirePayload::U64(x)) => {
                assert_eq!(*x, 40_000);
                40_000.0
            }
            (13, WirePayload::F64(x)) => {
                assert_eq!(*x, 2.5);
                2.5
            }
            (14, WirePayload::F32s(v)) => {
                assert_eq!(v, &[1.0, 2.0, 3.0]);
                6.0
            }
            (15, WirePayload::Bytes(b)) => {
                assert_eq!(b, &[1, 2, 3, 4]);
                10.0
            }
            other => panic!("echo chare: unexpected message {other:?}"),
        };
        self.sum += add;
        self.got += 1;
        if self.got == ECHO_KINDS {
            ctx.contribute(self.sum);
        }
    }
}

#[test]
fn every_payload_kind_crosses_the_wire_intact() {
    // node 0 sends one message per payload shape to node 1's echo
    // chare; node 1 folds the checksum into the cluster reduction, so
    // the root's single series entry proves every shape arrived intact.
    let reports = Cluster::loopback(2, cfg(1), NetConfig::default(), |node, h| {
        let spec = JobSpec::new("echo")
            .kernel(synth_descriptor("dist_echo", 4))
            .chare(ECHO_ID, 0, Box::new(EchoChare { got: 0, sum: 0.0 }));
        if node == NodeId(0) {
            spec.driver(move |_| {
                let payloads = [
                    (10, WirePayload::Empty),
                    (11, WirePayload::U32(7)),
                    (12, WirePayload::U64(40_000)),
                    (13, WirePayload::F64(2.5)),
                    (14, WirePayload::F32s(vec![1.0, 2.0, 3.0])),
                    (15, WirePayload::Bytes(vec![1, 2, 3, 4])),
                ];
                for (method, p) in payloads {
                    h.send_remote(NodeId(1), ECHO_ID, method, p);
                }
                let (n, total) =
                    h.reduce(0, 0, 0.0).expect("root owns the total");
                assert_eq!(n, 1, "only node 1's chare contributes");
                Ok(vec![total])
            })
        } else {
            spec.driver(move |ctx| {
                let local = ctx.await_reduction(1)?;
                ctx.await_quiescence();
                assert!(h.reduce(0, 1, local).is_none());
                Ok(Vec::new())
            })
        }
    })
    .unwrap();

    // 1 + 7 + 40000 + 2.5 + 6 + 10
    assert_eq!(reports[0].series, vec![40_026.5]);
    assert!(reports[1].series.is_empty());
    assert_cluster_books_balance(&reports);
}

#[test]
fn tcp_mesh_round_trips_on_localhost() {
    // bind both listeners on port 0 first so the mesh knows its
    // addresses, then run a real two-endpoint cluster over sockets
    let listeners: Vec<TcpListener> = (0..2)
        .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
        .collect();
    let addrs: Vec<String> = listeners
        .iter()
        .map(|l| l.local_addr().unwrap().to_string())
        .collect();

    let count = 30;
    let rounds = 2;
    let handles: Vec<_> = listeners
        .into_iter()
        .enumerate()
        .map(|(i, listener)| {
            let addrs = addrs.clone();
            thread::spawn(move || {
                let t = Tcp::with_listener(i as u32, listener, &addrs)
                    .expect("mesh up");
                ClusterNode::run(
                    cfg(1),
                    NetConfig::default(),
                    Arc::new(t) as Arc<dyn Transport>,
                    |h| cluster_burst_spec("dist_tcp", 4, count, rounds, h),
                )
                .expect("node run")
            })
        })
        .collect();
    let mut reports: Vec<NodeReport> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    reports.sort_by_key(|r| r.node.0);

    assert_eq!(
        reports[0].series,
        vec![(2 * count * 4) as f64; rounds],
        "TCP root series must be the exact cluster physics"
    );
    assert!(reports[1].series.is_empty());
    assert_eq!(reports[0].peer_summaries.len(), 1);
    assert_cluster_books_balance(&reports);
    // real sockets carried real traffic
    assert!(reports.iter().all(|r| r.pool.wire_bytes_out > 0));
}
