//! Pipelined GpuService vs synchronous Executor vs the seed native path:
//! bitwise equivalence.
//!
//! The pipelined service stages launches on a dedicated thread through the
//! staging arena while the engine executes; the synchronous executor
//! pipelines only within a split launch. Both must produce *bitwise
//! identical* `Completion::out` for every registered payload kind --
//! including launches that split across `max_batch` -- because padding,
//! chunking, and kernel arithmetic are shared code.
//!
//! `registry_runtime_matches_seed_native_reference` additionally proves
//! the registry migration harmless: for every payload kind the
//! registry-driven runtime (devices 1 and 2) reproduces, bit for bit, the
//! outputs of the pre-redesign seed path — per-slot native kernels over
//! the same buffers, which is exactly what the seed sim backend computed.

use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::Duration;

mod common;

use gcharm::apps::spmv::{self, SpmvConfig};
use gcharm::coordinator::{
    ChareId, Config, JobSpec, LaunchModePolicy, ResidencyPolicy, Runtime,
};
use gcharm::runtime::kernel::TileKernel;
use gcharm::runtime::native::{cpu_ewald, cpu_gravity, cpu_md_interact};
use gcharm::runtime::shapes::{
    INTERACTIONS, INTER_W, KTAB_W, KTABLE, MD_PAD_POS, MD_W, PARTICLE_W,
    PARTS_PER_BUCKET, PARTS_PER_PATCH,
};
use gcharm::runtime::{
    default_artifacts_dir, CoalescingClass, Completion, DevicePool, Executor,
    GpuService, LaunchMode, LaunchSpec, Payload,
};
use gcharm::util::Rng;

const EPS2: f32 = 1e-2;
const MD_PARAMS: [f32; 3] = [1.0, 0.04, 1.0];

fn ktab() -> Vec<f32> {
    let mut ktab = vec![0.0f32; KTABLE * KTAB_W];
    // a few active k-vectors so Ewald outputs are nontrivial
    for (i, row) in [
        [1.0, 0.0, 0.0, 0.5],
        [0.0, 1.0, 0.0, 0.25],
        [1.0, 1.0, 0.0, 0.125],
    ]
    .iter()
    .enumerate()
    {
        ktab[i * KTAB_W..(i + 1) * KTAB_W].copy_from_slice(row);
    }
    ktab
}

fn kernels() -> Vec<Arc<TileKernel>> {
    gcharm::runtime::builtin_kernels(EPS2, ktab(), MD_PARAMS)
}

fn gravity() -> Arc<TileKernel> {
    Arc::new(TileKernel::gravity(EPS2))
}

fn gravity_payload(rng: &mut Rng, batch: usize) -> Payload {
    let mut parts = vec![0.0f32; batch * PARTS_PER_BUCKET * PARTICLE_W];
    let mut inters = vec![0.0f32; batch * INTERACTIONS * INTER_W];
    for v in parts.iter_mut().chain(inters.iter_mut()) {
        *v = rng.range(-1.0, 1.0) as f32;
    }
    Payload::Tile { kernel: gravity(), bufs: vec![parts, inters], batch }
}

fn gather_payload(rng: &mut Rng, batch: usize, rows: usize) -> Payload {
    let mut pool = vec![0.0f32; rows * PARTICLE_W];
    for v in pool.iter_mut() {
        *v = rng.range(-1.0, 1.0) as f32;
    }
    let idx: Vec<i32> = (0..batch * PARTS_PER_BUCKET)
        .map(|_| rng.below(rows) as i32)
        .collect();
    let mut inters = vec![0.0f32; batch * INTERACTIONS * INTER_W];
    for v in inters.iter_mut() {
        *v = rng.range(-1.0, 1.0) as f32;
    }
    Payload::TileGather {
        kernel: gravity(),
        pool: Arc::new(pool),
        idx,
        bufs: vec![inters],
        batch,
    }
}

fn ewald_payload(rng: &mut Rng, batch: usize) -> Payload {
    let mut parts = vec![0.0f32; batch * PARTS_PER_BUCKET * PARTICLE_W];
    for v in parts.iter_mut() {
        *v = rng.range(-2.0, 2.0) as f32;
    }
    Payload::Tile {
        kernel: Arc::new(TileKernel::ewald(ktab())),
        bufs: vec![parts],
        batch,
    }
}

fn md_payload(rng: &mut Rng, batch: usize) -> Payload {
    let mut pa = vec![MD_PAD_POS; batch * PARTS_PER_PATCH * MD_W];
    let mut pb = vec![MD_PAD_POS; batch * PARTS_PER_PATCH * MD_W];
    // half the slots filled with live particles in a dense box
    for slot in 0..batch {
        for j in 0..PARTS_PER_PATCH / 2 {
            let o = (slot * PARTS_PER_PATCH + j) * MD_W;
            pa[o] = rng.range(0.0, 2.0) as f32;
            pa[o + 1] = rng.range(0.0, 2.0) as f32;
            pb[o] = rng.range(0.0, 2.0) as f32;
            pb[o + 1] = rng.range(0.0, 2.0) as f32;
        }
    }
    Payload::Tile {
        kernel: Arc::new(TileKernel::md_force(MD_PARAMS)),
        bufs: vec![pa, pb],
        batch,
    }
}

fn payloads() -> Vec<(&'static str, Payload, CoalescingClass)> {
    let mut rng = Rng::new(42);
    vec![
        // unsplit launches
        ("gravity small", gravity_payload(&mut rng, 5), CoalescingClass::Contiguous),
        ("gather small", gather_payload(&mut rng, 7, 512), CoalescingClass::RandomGather),
        ("ewald small", ewald_payload(&mut rng, 9), CoalescingClass::Contiguous),
        ("md small", md_payload(&mut rng, 6), CoalescingClass::Contiguous),
        // launches splitting across max_batch (128 on the synthetic ladder)
        ("gravity split", gravity_payload(&mut rng, 150), CoalescingClass::Contiguous),
        ("gather split", gather_payload(&mut rng, 140, 1024), CoalescingClass::SortedGather),
        ("ewald split", ewald_payload(&mut rng, 200), CoalescingClass::Contiguous),
        ("md split", md_payload(&mut rng, 130), CoalescingClass::Contiguous),
    ]
}

/// The pre-redesign seed path: per-slot native kernels over the same
/// buffers (what the seed's enum-matching sim backend computed).
fn seed_reference(payload: &Payload) -> Vec<f32> {
    let kt = ktab();
    match payload {
        Payload::Tile { kernel, bufs, batch } => {
            let mut out = Vec::new();
            for s in 0..*batch {
                out.extend(match &*kernel.name {
                    "gravity" => {
                        let ps = PARTS_PER_BUCKET * PARTICLE_W;
                        let is = INTERACTIONS * INTER_W;
                        cpu_gravity(
                            &bufs[0][s * ps..(s + 1) * ps],
                            &bufs[1][s * is..(s + 1) * is],
                            EPS2,
                        )
                    }
                    "ewald" => {
                        let ps = PARTS_PER_BUCKET * PARTICLE_W;
                        cpu_ewald(&bufs[0][s * ps..(s + 1) * ps], &kt)
                    }
                    "md_force" => {
                        let ms = PARTS_PER_PATCH * MD_W;
                        cpu_md_interact(
                            &bufs[0][s * ms..(s + 1) * ms],
                            &bufs[1][s * ms..(s + 1) * ms],
                            MD_PARAMS,
                        )
                    }
                    other => panic!("unexpected family {other}"),
                });
            }
            out
        }
        Payload::TileGather { pool, idx, bufs, batch, .. } => {
            let mut out = Vec::new();
            let mut parts = vec![0.0f32; PARTS_PER_BUCKET * PARTICLE_W];
            for s in 0..*batch {
                for (j, &row) in idx
                    [s * PARTS_PER_BUCKET..(s + 1) * PARTS_PER_BUCKET]
                    .iter()
                    .enumerate()
                {
                    let row = row as usize;
                    parts[j * PARTICLE_W..(j + 1) * PARTICLE_W]
                        .copy_from_slice(
                            &pool[row * PARTICLE_W..(row + 1) * PARTICLE_W],
                        );
                }
                let is = INTERACTIONS * INTER_W;
                out.extend(cpu_gravity(
                    &parts,
                    &bufs[0][s * is..(s + 1) * is],
                    EPS2,
                ));
            }
            out
        }
    }
}

#[test]
fn pipelined_service_matches_sync_executor_bitwise() {
    let specs: Vec<(&str, LaunchSpec)> = payloads()
        .into_iter()
        .enumerate()
        .map(|(i, (label, payload, pattern))| {
            (
                label,
                LaunchSpec {
                    id: i as u64,
                    payload,
                    transfer_bytes: 4096,
                    pattern,
                    mode: LaunchMode::PerBatch,
                },
            )
        })
        .collect();

    // Synchronous reference.
    let mut sync =
        Executor::new(&default_artifacts_dir(), kernels()).expect("executor");
    let reference: Vec<Completion> = specs
        .iter()
        .map(|(label, s)| {
            sync.run(s.clone()).unwrap_or_else(|e| panic!("{label}: {e}"))
        })
        .collect();

    // Pipelined service.
    let (done_tx, done_rx) = channel();
    let svc = GpuService::spawn(&default_artifacts_dir(), kernels(), done_tx)
        .expect("gpu service");
    for (_, s) in &specs {
        svc.submit(s.clone()).expect("submit");
    }
    let mut piped: Vec<Completion> = Vec::new();
    for _ in 0..specs.len() {
        piped.push(
            done_rx
                .recv_timeout(Duration::from_secs(120))
                .expect("completion")
                .expect("launch ok"),
        );
    }
    piped.sort_by_key(|c| c.id);

    for ((label, _), (want, got)) in
        specs.iter().zip(reference.iter().zip(&piped))
    {
        assert_eq!(want.id, got.id);
        assert_eq!(want.batch, got.batch, "{label}: batch mismatch");
        assert_eq!(
            want.out.len(),
            got.out.len(),
            "{label}: output length mismatch"
        );
        for (k, (a, b)) in want.out.iter().zip(&got.out).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{label}: element {k} differs: {a} vs {b}"
            );
        }
        // modeled costs are derived from the same chunking: identical too
        assert_eq!(
            want.modeled.kernel.to_bits(),
            got.modeled.kernel.to_bits(),
            "{label}: modeled kernel cost differs"
        );
        assert_eq!(
            want.modeled.transfer.to_bits(),
            got.modeled.transfer.to_bits(),
            "{label}: modeled transfer cost differs"
        );
    }
}

/// All-payload spec set shared by the device-pool equivalence tests.
fn pool_specs() -> Vec<(&'static str, LaunchSpec)> {
    payloads()
        .into_iter()
        .enumerate()
        .map(|(i, (label, payload, pattern))| {
            (
                label,
                LaunchSpec {
                    id: i as u64,
                    payload,
                    transfer_bytes: 4096,
                    pattern,
                    mode: LaunchMode::PerBatch,
                },
            )
        })
        .collect()
}

/// Run the spec set through a pool of `devices`, assigning launch i to
/// device i % devices; completions sorted by id.
fn run_pool(devices: usize, specs: &[(&str, LaunchSpec)]) -> Vec<Completion> {
    let (done_tx, done_rx) = channel();
    let pool = DevicePool::spawn(
        &default_artifacts_dir(),
        kernels(),
        devices,
        done_tx,
    )
    .expect("device pool");
    for (i, (_, s)) in specs.iter().enumerate() {
        pool.submit(i % devices, s.clone()).expect("submit");
    }
    let mut out: Vec<Completion> = (0..specs.len())
        .map(|_| {
            done_rx
                .recv_timeout(Duration::from_secs(120))
                .expect("completion")
                .expect("launch ok")
        })
        .collect();
    out.sort_by_key(|c| c.id);
    out
}

#[test]
fn registry_runtime_matches_seed_native_reference() {
    // The registry-migrated path must be bitwise identical to the seed
    // path (per-slot native kernels) for every payload kind, on 1 and 2
    // devices.
    let specs = pool_specs();
    for devices in [1usize, 2] {
        let got = run_pool(devices, &specs);
        for ((label, s), c) in specs.iter().zip(&got) {
            let want = seed_reference(&s.payload);
            assert_eq!(
                want.len(),
                c.out.len(),
                "{label} ({devices} devices): output length"
            );
            let bits_w: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
            let bits_g: Vec<u32> = c.out.iter().map(|x| x.to_bits()).collect();
            assert_eq!(
                bits_w, bits_g,
                "{label} ({devices} devices): drifted from the seed path"
            );
        }
    }
}

#[test]
fn device_pool_single_device_matches_sync_executor_bitwise() {
    // `devices = 1` must reproduce the pre-pool single-service path
    // bitwise: the sync Executor is the unchanged reference.
    let specs = pool_specs();
    let mut sync =
        Executor::new(&default_artifacts_dir(), kernels()).expect("executor");
    let reference: Vec<Completion> = specs
        .iter()
        .map(|(label, s)| {
            sync.run(s.clone()).unwrap_or_else(|e| panic!("{label}: {e}"))
        })
        .collect();

    let pooled = run_pool(1, &specs);
    for ((label, _), (want, got)) in
        specs.iter().zip(reference.iter().zip(&pooled))
    {
        assert_eq!(got.device, 0, "{label}: single-device tag");
        assert_eq!(want.batch, got.batch, "{label}: batch mismatch");
        let bits_a: Vec<u32> = want.out.iter().map(|x| x.to_bits()).collect();
        let bits_b: Vec<u32> = got.out.iter().map(|x| x.to_bits()).collect();
        assert_eq!(bits_a, bits_b, "{label}: outputs differ");
        assert_eq!(
            want.modeled.kernel.to_bits(),
            got.modeled.kernel.to_bits(),
            "{label}: modeled kernel cost differs"
        );
        assert_eq!(
            want.modeled.transfer.to_bits(),
            got.modeled.transfer.to_bits(),
            "{label}: modeled transfer cost differs"
        );
    }
}

#[test]
fn device_pool_sharded_deterministic_across_runs() {
    // devices in {2, 4}: for every payload kind (incl. split launches),
    // two identical runs with identical device assignment must produce
    // bitwise-identical completions, each tagged with its device.
    for devices in [2usize, 4] {
        let specs = pool_specs();
        let a = run_pool(devices, &specs);
        let b = run_pool(devices, &specs);
        for (i, ((label, _), (ca, cb))) in
            specs.iter().zip(a.iter().zip(&b)).enumerate()
        {
            assert_eq!(ca.device, i % devices, "{label}: device assignment");
            assert_eq!(cb.device, i % devices);
            assert_eq!(ca.batch, cb.batch, "{label}: batch mismatch");
            let bits_a: Vec<u32> =
                ca.out.iter().map(|x| x.to_bits()).collect();
            let bits_b: Vec<u32> =
                cb.out.iter().map(|x| x.to_bits()).collect();
            assert_eq!(
                bits_a, bits_b,
                "{label}: {devices}-device run not deterministic"
            );
            assert_eq!(
                ca.modeled.kernel.to_bits(),
                cb.modeled.kernel.to_bits(),
                "{label}: modeled kernel cost not deterministic"
            );
        }
        // sharded outputs also match the single-device reference bitwise
        let single = run_pool(1, &specs);
        for ((label, _), (cs, cp)) in specs.iter().zip(single.iter().zip(&a))
        {
            let bits_s: Vec<u32> =
                cs.out.iter().map(|x| x.to_bits()).collect();
            let bits_p: Vec<u32> =
                cp.out.iter().map(|x| x.to_bits()).collect();
            assert_eq!(
                bits_s, bits_p,
                "{label}: {devices}-device outputs drift from single device"
            );
        }
    }
}

#[test]
fn pipelined_service_interleaves_distinct_kernels() {
    // Back-to-back launches of different kinds exercise arena pools for
    // several variants at once; outputs must still match the sync path.
    let mut rng = Rng::new(7);
    let specs: Vec<LaunchSpec> = (0..12)
        .map(|i| {
            let payload = match i % 4 {
                0 => gravity_payload(&mut rng, 130),
                1 => ewald_payload(&mut rng, 40),
                2 => md_payload(&mut rng, 33),
                _ => gather_payload(&mut rng, 20, 256),
            };
            LaunchSpec {
                id: i,
                payload,
                transfer_bytes: 0,
                pattern: CoalescingClass::Contiguous,
                mode: LaunchMode::PerBatch,
            }
        })
        .collect();

    let mut sync =
        Executor::new(&default_artifacts_dir(), kernels()).expect("executor");
    let reference: Vec<Completion> =
        specs.iter().map(|s| sync.run(s.clone()).unwrap()).collect();

    let (done_tx, done_rx) = channel();
    let svc = GpuService::spawn(&default_artifacts_dir(), kernels(), done_tx)
        .expect("gpu service");
    for s in &specs {
        svc.submit(s.clone()).unwrap();
    }
    let mut piped: Vec<Completion> = (0..specs.len())
        .map(|_| {
            done_rx
                .recv_timeout(Duration::from_secs(120))
                .expect("completion")
                .expect("launch ok")
        })
        .collect();
    piped.sort_by_key(|c| c.id);
    for (want, got) in reference.iter().zip(&piped) {
        assert_eq!(want.id, got.id);
        let bits_a: Vec<u32> =
            want.out.iter().map(|x| x.to_bits()).collect();
        let bits_b: Vec<u32> = got.out.iter().map(|x| x.to_bits()).collect();
        assert_eq!(bits_a, bits_b, "launch {} differs", want.id);
    }
}

// ---------------------------------------------------------------------------
// Concurrent jobs vs sequential single-job runtimes: bitwise equivalence.
// ---------------------------------------------------------------------------

fn eqsum_spec(rounds: usize, count: usize) -> JobSpec {
    common::BurstJob {
        name: "eqsum",
        desc: common::synth_descriptor("eqsum", 4),
        // deliberately collides with spmv's chare collection: ids are
        // namespaced per job
        id: ChareId::new(3, 0),
        pe: 1,
        rows: 4,
        count,
        rounds,
        barrier: None,
    }
    .spec()
}

/// SpMV sized so every row fits one tile chunk: per-row accumulation is a
/// single partial, so the final iterate is bitwise deterministic however
/// the runtime combines, splits, or steals.
fn eq_spmv_cfg() -> SpmvConfig {
    let mut cfg = SpmvConfig::new(200);
    cfg.max_row_nnz = 96; // < SPMV_TILE: one chunk per row
    cfg.iters = 3;
    cfg.seed = 11;
    cfg
}

fn runtime_cfg(devices: usize) -> Config {
    Config { pes: 2, devices, ..Config::default() }
}

/// Final spmv iterate (bit pattern) and eqsum series, run sequentially on
/// fresh single-job runtimes.
fn run_sequential(devices: usize) -> (Vec<u32>, Vec<f64>) {
    let cfg = eq_spmv_cfg();
    let master = Arc::new(Mutex::new(vec![0.0f32; cfg.rows]));
    let rt = Runtime::new(runtime_cfg(devices)).unwrap();
    rt.submit_job(spmv::job_spec_with_master(&cfg, "spmv", master.clone()))
        .unwrap()
        .wait()
        .unwrap();
    rt.shutdown();

    let rt = Runtime::new(runtime_cfg(devices)).unwrap();
    let series = rt
        .submit_job(eqsum_spec(3, 300))
        .unwrap()
        .wait()
        .unwrap()
        .series;
    rt.shutdown();

    let bits = master.lock().unwrap().iter().map(|x| x.to_bits()).collect();
    (bits, series)
}

/// The same two jobs, concurrent on ONE runtime.
fn run_concurrent(devices: usize) -> (Vec<u32>, Vec<f64>, u64) {
    let cfg = eq_spmv_cfg();
    let master = Arc::new(Mutex::new(vec![0.0f32; cfg.rows]));
    let rt = Runtime::new(runtime_cfg(devices)).unwrap();
    let a = rt
        .submit_job(spmv::job_spec_with_master(&cfg, "spmv", master.clone()))
        .unwrap();
    let b = rt.submit_job(eqsum_spec(3, 300)).unwrap();
    a.wait().unwrap();
    let series = b.wait().unwrap().series;
    let pool = rt.shutdown();
    assert_eq!(pool.jobs.len(), 2);
    let bits = master.lock().unwrap().iter().map(|x| x.to_bits()).collect();
    (bits, series, pool.cross_job_launches)
}

#[test]
fn concurrent_jobs_match_sequential_runtimes_bitwise() {
    for devices in [1usize, 2] {
        let (seq_x, seq_series) = run_sequential(devices);
        let (conc_x, conc_series, cross) = run_concurrent(devices);
        assert_eq!(
            seq_x, conc_x,
            "{devices} device(s): spmv iterate drifted under co-tenancy"
        );
        assert_eq!(
            seq_series, conc_series,
            "{devices} device(s): eqsum series drifted under co-tenancy"
        );
        // different families never share launches
        assert_eq!(cross, 0, "{devices} device(s)");
    }
}

/// `Config { residency: Lru }` is the seed runtime: the knob must
/// reproduce the pre-ISSUE-7 path exactly. The concurrent two-job run
/// under explicit Lru matches the default-config run bitwise, and the
/// prefetch machinery stays completely dark.
#[test]
fn lru_residency_reproduces_seed_runtime_bitwise() {
    for devices in [1usize, 2] {
        let cfg = eq_spmv_cfg();
        let master = Arc::new(Mutex::new(vec![0.0f32; cfg.rows]));
        let rt = Runtime::new(Config {
            residency: ResidencyPolicy::Lru,
            ..runtime_cfg(devices)
        })
        .unwrap();
        let a = rt
            .submit_job(spmv::job_spec_with_master(
                &cfg,
                "spmv",
                master.clone(),
            ))
            .unwrap();
        let b = rt.submit_job(eqsum_spec(3, 300)).unwrap();
        a.wait().unwrap();
        let lru_series = b.wait().unwrap().series;
        let pool = rt.shutdown();
        let lru_x: Vec<u32> =
            master.lock().unwrap().iter().map(|x| x.to_bits()).collect();

        let (def_x, def_series, _) = run_concurrent(devices);
        assert_eq!(
            lru_x, def_x,
            "{devices} device(s): Lru drifted from the default runtime"
        );
        assert_eq!(lru_series, def_series, "{devices} device(s)");

        // seed surface: no prefetch counters, no staged-ahead bytes
        assert_eq!(pool.prefetch_hits, 0, "{devices} device(s)");
        assert_eq!(pool.prefetch_wasted, 0, "{devices} device(s)");
        assert_eq!(pool.prefetch_bytes, 0, "{devices} device(s)");
        for k in &pool.kind_stats {
            assert_eq!(k.prefetch_hits, 0, "{}", k.name);
            assert_eq!(k.prefetch_wasted, 0, "{}", k.name);
        }
    }
}

/// The concurrent two-job run under each static launch-mode policy.
fn run_concurrent_with_mode(
    devices: usize,
    mode: LaunchModePolicy,
) -> (Vec<u32>, Vec<f64>, gcharm::coordinator::PoolReport) {
    let cfg = eq_spmv_cfg();
    let master = Arc::new(Mutex::new(vec![0.0f32; cfg.rows]));
    let rt = Runtime::new(Config {
        launch_mode: mode,
        ..runtime_cfg(devices)
    })
    .unwrap();
    let a = rt
        .submit_job(spmv::job_spec_with_master(&cfg, "spmv", master.clone()))
        .unwrap();
    let b = rt.submit_job(eqsum_spec(3, 300)).unwrap();
    a.wait().unwrap();
    let series = b.wait().unwrap().series;
    let pool = rt.shutdown();
    let bits = master.lock().unwrap().iter().map(|x| x.to_bits()).collect();
    (bits, series, pool)
}

/// Persistent-kernel mode (ISSUE 8) changes only modeled time: the same
/// f32 arithmetic runs either way, so the spmv iterate and the eqsum
/// series must be bitwise identical to the per-batch runtime on 1 and 2
/// devices — while the mode counters prove both paths actually ran their
/// advertised mode, and the partition covers every launch.
#[test]
fn persistent_mode_matches_per_batch_bitwise() {
    for devices in [1usize, 2] {
        let (pb_x, pb_series, pb_pool) =
            run_concurrent_with_mode(devices, LaunchModePolicy::PerBatch);
        let (ps_x, ps_series, ps_pool) =
            run_concurrent_with_mode(devices, LaunchModePolicy::Persistent);
        assert_eq!(
            pb_x, ps_x,
            "{devices} device(s): spmv iterate drifted under persistent mode"
        );
        assert_eq!(
            pb_series, ps_series,
            "{devices} device(s): eqsum series drifted under persistent mode"
        );
        // the static modes really ran what they advertise
        assert_eq!(
            pb_pool.persistent_batches, 0,
            "{devices} device(s): per-batch run used a resident loop"
        );
        assert_eq!(pb_pool.per_batch_launches, pb_pool.launches);
        assert!(
            ps_pool.persistent_batches > 0,
            "{devices} device(s): persistent run never used its rings"
        );
        assert_eq!(
            ps_pool.persistent_batches + ps_pool.per_batch_launches,
            ps_pool.launches,
            "{devices} device(s): launch-mode partition broken"
        );
    }
}
