//! Registry round-trip properties: N app-registered synthetic kernel
//! families driven through the combiner layer and the full runtime.
//!
//! Invariants covered:
//!   - registering N descriptors yields table-driven combiners whose
//!     flush sizes never exceed each family's occupancy-derived cap, and
//!     mixed-kind bursts are neither dropped nor duplicated;
//!   - shape checking rejects malformed tiles naming the offending arg;
//!   - a full `GCharm` run over registered-only families accounts every
//!     submitted request in the per-kind report and respects per-kind
//!     launch caps.

use std::sync::Arc;

use gcharm::coordinator::{
    Chare, ChareId, CombinePolicy, Combiner, Config, Ctx, GCharm, JobId,
    KernelDescriptor, KernelKindId, KernelRegistry, Msg, Pending, Tile,
    WorkDraft, WorkRequest, WrResult, METHOD_RESULT,
};
use gcharm::runtime::kernel::{TileArgSpec, TileKernel};
use gcharm::runtime::KernelResources;
use gcharm::util::Rng;

/// Per-slot kernel: sum of the tile entries.
fn sum_slot(args: &[&[f32]], _c: &[f32]) -> Vec<f32> {
    vec![args[0].iter().sum()]
}

/// A synthetic family: `rows x 1` tile, 1x1 output, resources varied by
/// `variant` so registered families get different occupancy caps.
fn synth_descriptor(name: String, rows: usize, variant: usize) -> KernelDescriptor {
    let resources = match variant % 3 {
        0 => KernelResources {
            threads_per_block: 128,
            regs_per_thread: 64,
            smem_per_block: 4096,
        }, // cap 104
        1 => KernelResources {
            threads_per_block: 128,
            regs_per_thread: 96,
            smem_per_block: 2048,
        }, // cap 65
        _ => KernelResources {
            threads_per_block: 64,
            regs_per_thread: 48,
            smem_per_block: 2048,
        }, // cap 208
    };
    KernelDescriptor {
        kernel: Arc::new(TileKernel {
            name: Arc::from(name.as_str()),
            args: vec![TileArgSpec { name: "tile", rows, width: 1, pad: 0.0 }],
            constant: Arc::new(Vec::new()),
            out_rows: 1,
            out_width: 1,
            resources,
            items_per_slot: rows as u64,
            reuse_arg: None,
            gather_name: None,
            entry_arg: None,
            slot_fn: sum_slot,
        }),
        combine: None,
        sort_by_slot: false,
        cpu_fallback: false,
        launch_mode: None,
    }
}

fn wr(kind: KernelKindId, id: u64, rows: usize) -> Pending {
    Pending {
        wr: WorkRequest {
            id,
            job: JobId(0),
            chare: ChareId::new(0, id as u32),
            kind,
            buffer: None,
            data_items: rows,
            tag: id,
            arrival: 0.0,
            payload: Tile::new(vec![vec![1.0; rows]]),
        },
        slot: None,
        staged_bytes: 0,
    }
}

#[test]
fn prop_registered_combiners_cap_and_conserve_mixed_bursts() {
    for seed in 0..30u64 {
        let mut rng = Rng::new(seed ^ 0xC0DE);
        let nkinds = 1 + rng.below(5);
        let mut reg = KernelRegistry::new();
        let mut rows = Vec::new();
        for k in 0..nkinds {
            let r = 1 + rng.below(16);
            rows.push(r);
            reg.register(synth_descriptor(format!("synth_{k}"), r, k))
                .unwrap();
        }
        // Combiners exactly as the coordinator builds them: one per kind,
        // occupancy-derived cap from the registered resources.
        let mut combiners: Vec<Combiner> = reg
            .descriptors()
            .iter()
            .map(|d| {
                Combiner::new(
                    d.combine.unwrap_or(CombinePolicy::Adaptive),
                    d.kernel.max_combine(),
                    d.sort_by_slot,
                )
            })
            .collect();
        let caps: Vec<usize> =
            reg.descriptors().iter().map(|d| d.kernel.max_combine()).collect();

        let n = 50 + rng.below(400);
        let mut submitted = vec![0usize; nkinds];
        let mut flushed: Vec<Vec<u64>> = vec![Vec::new(); nkinds];
        let mut now = 0.0f64;
        for i in 0..n {
            let k = rng.below(nkinds);
            now += rng.exponential(0.0005);
            combiners[k].insert(wr(KernelKindId(k), i as u64, rows[k]), now);
            submitted[k] += 1;
            for (kk, c) in combiners.iter_mut().enumerate() {
                while let Some(b) = c.poll(now) {
                    assert!(
                        b.items.len() <= caps[kk],
                        "seed {seed}: kind {kk} flushed {} > cap {}",
                        b.items.len(),
                        caps[kk]
                    );
                    for p in b.items {
                        assert_eq!(p.wr.kind, KernelKindId(kk));
                        flushed[kk].push(p.wr.id);
                    }
                }
            }
        }
        for (kk, c) in combiners.iter_mut().enumerate() {
            while let Some(b) = c.force_flush() {
                assert!(b.items.len() <= caps[kk]);
                for p in b.items {
                    flushed[kk].push(p.wr.id);
                }
            }
            assert!(c.is_empty());
        }
        for k in 0..nkinds {
            let mut ids = flushed[k].clone();
            let total = ids.len();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), total, "seed {seed}: kind {k} duplicated");
            assert_eq!(
                total, submitted[k],
                "seed {seed}: kind {k} dropped requests"
            );
        }
    }
}

#[test]
fn prop_shape_check_reports_expected_and_actual() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed ^ 0xFACE);
        let rows = 1 + rng.below(32);
        let mut reg = KernelRegistry::new();
        let id = reg
            .register(synth_descriptor("s".to_string(), rows, seed as usize))
            .unwrap();
        let good = Tile::new(vec![vec![0.0; rows]]);
        assert!(reg.check(id, &good).is_ok());
        let bad_len = rows + 1 + rng.below(8);
        let bad = Tile::new(vec![vec![0.0; bad_len]]);
        let e = reg.check(id, &bad).unwrap_err();
        assert_eq!(e.arg, "tile", "seed {seed}");
        assert_eq!(e.expected, rows);
        assert_eq!(e.actual, bad_len);
    }
}

/// A family with BOTH a reuse arg and a CPU fallback: requests pin table
/// slots at submission, then the hybrid split sends a prefix to the CPU
/// pool. Regression target: the CPU prefix must release its pins (the CPU
/// completion path never touches the chare table).
fn reuse_hybrid_descriptor(rows: usize) -> KernelDescriptor {
    KernelDescriptor {
        kernel: Arc::new(TileKernel {
            name: Arc::from("reuse_hybrid"),
            args: vec![TileArgSpec { name: "tile", rows, width: 1, pad: 0.0 }],
            constant: Arc::new(Vec::new()),
            out_rows: 1,
            out_width: 1,
            resources: KernelResources {
                threads_per_block: 128,
                regs_per_thread: 64,
                smem_per_block: 4096,
            },
            items_per_slot: rows as u64,
            reuse_arg: Some(0),
            gather_name: Some(Arc::from("reuse_hybrid_gather")),
            entry_arg: None,
            slot_fn: sum_slot,
        }),
        combine: None,
        sort_by_slot: true,
        cpu_fallback: true,
        launch_mode: None,
    }
}

/// Bursts requests with reuse buffer ids; repeated ids carry identical
/// data (reuse-correct), so CPU- and GPU-side results agree.
struct ReuseBurster {
    id: ChareId,
    kind: KernelKindId,
    rows: usize,
    count: usize,
    nbuf: usize,
    pending: usize,
    sum: f64,
}

impl Chare for ReuseBurster {
    fn receive(&mut self, msg: Msg, ctx: &mut Ctx) {
        match msg.method {
            METHOD_GO => {
                self.pending = self.count;
                self.sum = 0.0;
                for i in 0..self.count {
                    let buf = (i % self.nbuf) as u64;
                    ctx.submit(WorkDraft {
                        chare: self.id,
                        kind: self.kind,
                        buffer: Some(buf),
                        data_items: self.rows,
                        tag: buf,
                        payload: Tile::new(vec![vec![
                            buf as f32;
                            self.rows
                        ]]),
                    })
                    .expect("registered tile shape");
                }
            }
            METHOD_RESULT => {
                let r: WrResult = msg.take();
                // every slot sums its tile: rows * buffer value
                assert_eq!(r.out[0], (self.rows as u64 * r.tag) as f32);
                self.sum += r.out[0] as f64;
                self.pending -= 1;
                if self.pending == 0 {
                    ctx.contribute(self.sum);
                }
            }
            other => panic!("unknown method {other}"),
        }
    }
}

#[test]
fn reuse_hybrid_family_releases_cpu_split_pins() {
    let rows = 4usize;
    let count = 300usize;
    let nbuf = 64usize;
    let mut rt = GCharm::new(Config { pes: 2, ..Config::default() }).unwrap();
    let kind = rt.register_kernel(reuse_hybrid_descriptor(rows)).unwrap();
    let id = ChareId::new(6, 0);
    rt.register(
        id,
        0,
        Box::new(ReuseBurster {
            id,
            kind,
            rows,
            count,
            nbuf,
            pending: 0,
            sum: 0.0,
        }),
    );
    rt.start().unwrap();
    let want: f64 = (0..count).map(|i| (rows * (i % nbuf)) as f64).sum();
    for _round in 0..2 {
        rt.send(id, Msg::new(METHOD_GO, ()));
        let got = rt.await_reduction(1);
        assert!((got - want).abs() < 1e-9, "sum {got} vs {want}");
        rt.await_quiescence();
        // The leak detector: invalidate_all debug_asserts on pinned
        // slots, so any pin leaked by the hybrid CPU prefix panics the
        // coordinator here (and the next round would stall on an
        // exhausted pool even in release builds).
        rt.invalidate_device_buffers();
    }
    let report = rt.shutdown();
    let ks = report.kind("reuse_hybrid").expect("kind stats");
    assert_eq!(ks.gpu_requests + ks.cpu_requests, 2 * count as u64);
    assert!(ks.cpu_requests > 0, "hybrid split never used the CPU side");
}

/// A chare that bursts `count` requests of one registered kind and
/// contributes once every result returned.
struct Burster {
    id: ChareId,
    kind: KernelKindId,
    rows: usize,
    count: usize,
    pending: usize,
    sum: f64,
}

const METHOD_GO: u32 = 1;

impl Chare for Burster {
    fn receive(&mut self, msg: Msg, ctx: &mut Ctx) {
        match msg.method {
            METHOD_GO => {
                self.pending = self.count;
                for i in 0..self.count {
                    ctx.submit(WorkDraft {
                        chare: self.id,
                        kind: self.kind,
                        buffer: None,
                        data_items: self.rows,
                        tag: i as u64,
                        payload: Tile::new(vec![vec![1.0; self.rows]]),
                    })
                    .expect("registered tile shape");
                }
            }
            METHOD_RESULT => {
                let r: WrResult = msg.take();
                assert_eq!(r.kind, self.kind);
                self.sum += r.out[0] as f64;
                self.pending -= 1;
                if self.pending == 0 {
                    ctx.contribute(self.sum);
                }
            }
            other => panic!("unknown method {other}"),
        }
    }
}

#[test]
fn full_stack_registered_bursts_respect_caps_and_accounting() {
    let mut rt = GCharm::new(Config { pes: 2, ..Config::default() }).unwrap();
    let mut kinds = Vec::new();
    let rows = [4usize, 8, 3];
    let counts = [220usize, 150, 90];
    for (k, &r) in rows.iter().enumerate() {
        kinds.push(
            rt.register_kernel(synth_descriptor(format!("burst_{k}"), r, k))
                .unwrap(),
        );
    }
    let caps: Vec<usize> = kinds
        .iter()
        .map(|&k| rt.kernel_registry().kernel(k).max_combine())
        .collect();
    for (k, &kind) in kinds.iter().enumerate() {
        let id = ChareId::new(5, k as u32);
        rt.register(
            id,
            k % 2,
            Box::new(Burster {
                id,
                kind,
                rows: rows[k],
                count: counts[k],
                pending: 0,
                sum: 0.0,
            }),
        );
    }
    rt.start().unwrap();
    for k in 0..kinds.len() {
        rt.send(ChareId::new(5, k as u32), Msg::new(METHOD_GO, ()));
    }
    // each request sums a tile of ones: per-chare sum = count * rows
    let total = rt.await_reduction(kinds.len() as u64);
    rt.await_quiescence();
    let report = rt.shutdown();

    let want_total: f64 = rows
        .iter()
        .zip(&counts)
        .map(|(&r, &c)| (r * c) as f64)
        .sum();
    assert!(
        (total - want_total).abs() < 1e-9,
        "summed outputs {total} vs {want_total}"
    );

    // per-kind accounting: every submitted request lands in its family's
    // stats, and launch counts respect the occupancy caps
    let submitted: u64 = counts.iter().map(|&c| c as u64).sum();
    assert_eq!(report.gpu_requests, submitted);
    assert_eq!(report.flushed_requests, submitted, "flush accounting");
    for (k, &kind) in kinds.iter().enumerate() {
        let ks = &report.kind_stats[kind.0];
        assert_eq!(ks.name, format!("burst_{k}"));
        assert_eq!(ks.gpu_requests, counts[k] as u64, "kind {k} requests");
        assert_eq!(ks.cpu_requests, 0, "GPU-only family");
        let min_launches = counts[k].div_ceil(caps[k]) as u64;
        assert!(
            ks.launches >= min_launches,
            "kind {k}: {} launches for {} requests under cap {}",
            ks.launches,
            counts[k],
            caps[k]
        );
    }
}
