//! Property-based tests on coordinator invariants.
//!
//! The vendored crate set has no proptest, so properties are driven by the
//! crate's own deterministic RNG over many random cases; failures print the
//! seed for replay. Invariants covered:
//!   - the combiner never exceeds maxSize, never drops or duplicates a
//!     request, preserves per-policy ordering;
//!   - the slot-sorted queue is always sorted and a permutation of inserts;
//!   - the hybrid split conserves requests and respects the ratio bound;
//!   - the device memory allocator never double-assigns a slot and honors
//!     pins;
//!   - occupancy results respect the hardware limits for arbitrary kernel
//!     resource descriptors.

use gcharm::coordinator::{
    Batch, ChareId, CombinePolicy, Combiner, HybridScheduler, JobId,
    KernelKindId, Pending, SplitPolicy, Tile, WorkRequest,
};
use gcharm::runtime::memory::DeviceMemory;
use gcharm::runtime::{occupancy, GpuSpec, KernelResources};
use gcharm::util::Rng;

const K0: KernelKindId = KernelKindId(0);

fn wr(id: u64, items: usize) -> WorkRequest {
    WorkRequest {
        id,
        job: JobId(0),
        chare: ChareId::new(0, id as u32),
        kind: K0,
        buffer: Some(id),
        data_items: items,
        tag: id,
        arrival: 0.0,
        payload: Tile::default(),
    }
}

fn pending(id: u64, slot: Option<u32>, items: usize) -> Pending {
    Pending { wr: wr(id, items), slot, staged_bytes: 0 }
}

/// Run one randomized combiner scenario; return all flushed batches.
fn combiner_scenario(seed: u64, policy: CombinePolicy, sort: bool) -> (Vec<Batch>, usize) {
    let mut rng = Rng::new(seed);
    let max_size = 1 + rng.below(32);
    let mut c = Combiner::new(policy, max_size, sort);
    let n = 1 + rng.below(300);
    let mut now = 0.0f64;
    let mut batches = Vec::new();
    for i in 0..n {
        now += rng.exponential(0.001);
        let slot = sort.then(|| rng.below(10_000) as u32);
        c.insert(pending(i as u64, slot, 1 + rng.below(100)), now);
        // random extra polls at random times
        if rng.below(3) == 0 {
            now += rng.exponential(0.002);
        }
        while let Some(b) = c.poll(now) {
            batches.push(b);
        }
    }
    while let Some(b) = c.force_flush() {
        batches.push(b);
    }
    assert!(c.is_empty());
    (batches, max_size)
}

#[test]
fn prop_combiner_conserves_and_caps_adaptive() {
    for seed in 0..60u64 {
        let (batches, max_size) =
            combiner_scenario(seed, CombinePolicy::Adaptive, false);
        let mut ids: Vec<u64> = batches
            .iter()
            .flat_map(|b| b.items.iter().map(|p| p.wr.id))
            .collect();
        let total = ids.len();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), total, "seed {seed}: duplicated request");
        assert_eq!(
            ids,
            (0..total as u64).collect::<Vec<_>>(),
            "seed {seed}: dropped request"
        );
        for b in &batches {
            assert!(
                b.items.len() <= max_size,
                "seed {seed}: batch {} > maxSize {max_size}",
                b.items.len()
            );
        }
    }
}

#[test]
fn prop_combiner_conserves_static() {
    for seed in 100..140u64 {
        let (batches, max_size) =
            combiner_scenario(seed, CombinePolicy::StaticEvery(17), false);
        let total: usize = batches.iter().map(|b| b.items.len()).sum();
        let mut ids: Vec<u64> = batches
            .iter()
            .flat_map(|b| b.items.iter().map(|p| p.wr.id))
            .collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), total, "seed {seed}");
        for b in &batches {
            assert!(b.items.len() <= max_size);
        }
    }
}

#[test]
fn prop_sorted_combiner_batches_are_slot_sorted() {
    for seed in 200..240u64 {
        let (batches, _) =
            combiner_scenario(seed, CombinePolicy::Adaptive, true);
        for b in &batches {
            let slots: Vec<u32> =
                b.items.iter().map(|p| p.slot.unwrap()).collect();
            assert!(
                slots.windows(2).all(|w| w[0] <= w[1]),
                "seed {seed}: unsorted batch {slots:?}"
            );
        }
    }
}

#[test]
fn prop_unsorted_adaptive_preserves_fifo() {
    for seed in 300..330u64 {
        let (batches, _) =
            combiner_scenario(seed, CombinePolicy::Adaptive, false);
        let ids: Vec<u64> = batches
            .iter()
            .flat_map(|b| b.items.iter().map(|p| p.wr.id))
            .collect();
        assert!(
            ids.windows(2).all(|w| w[0] < w[1]),
            "seed {seed}: arrival order violated"
        );
    }
}

#[test]
fn prop_hybrid_split_conserves_and_bounds() {
    for seed in 0..80u64 {
        let mut rng = Rng::new(seed ^ 0xABCD);
        let policy = if rng.below(2) == 0 {
            SplitPolicy::StaticCount
        } else {
            SplitPolicy::AdaptiveItems
        };
        let mut h = HybridScheduler::new(policy);
        if rng.below(4) != 0 {
            h.record_cpu(K0, 1 + rng.below(100), rng.f64() + 1e-6);
            h.record_gpu(K0, 1 + rng.below(100), rng.f64() + 1e-6);
        }
        let n = 1 + rng.below(100);
        let q: Vec<Pending> = (0..n)
            .map(|i| pending(i as u64, None, 1 + rng.below(200)))
            .collect();
        let total_items: usize = q.iter().map(|p| p.wr.data_items).sum();
        let (cpu, gpu) = h.split(K0, q);
        assert_eq!(cpu.len() + gpu.len(), n, "seed {seed}: lost requests");
        // order preserved
        let ids: Vec<u64> = cpu.iter().chain(&gpu).map(|p| p.wr.id).collect();
        assert_eq!(ids, (0..n as u64).collect::<Vec<_>>(), "seed {seed}");
        // adaptive: cpu items never exceed target by more than one request
        if policy == SplitPolicy::AdaptiveItems {
            let cpu_items: usize = cpu.iter().map(|p| p.wr.data_items).sum();
            let target = total_items as f64 * h.cpu_share(K0);
            assert!(
                cpu_items as f64 <= target + 1.0 + 200.0,
                "seed {seed}: cpu overloaded {cpu_items} vs target {target}"
            );
        }
    }
}

#[test]
fn prop_memory_never_double_assigns() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed ^ 0xBEEF);
        let cap = 1 + rng.below(32);
        let mut m = DeviceMemory::new(cap);
        let mut pinned: Vec<u64> = Vec::new();
        for step in 0..500 {
            let id = rng.below(cap * 3) as u64;
            match rng.below(10) {
                0..=6 => {
                    if let Some(r) = m.acquire(id) {
                        let slot = r.slot();
                        assert!(slot < cap, "seed {seed} step {step}");
                    } else {
                        // every slot pinned: legal only if pins >= cap
                        assert!(pinned.len() >= cap, "seed {seed} step {step}");
                    }
                }
                7..=8 => {
                    if m.peek(id).is_some() {
                        m.pin(id);
                        pinned.push(id);
                    }
                }
                _ => {
                    if let Some(pos) = pinned.iter().position(|&p| p == id) {
                        m.unpin(id);
                        pinned.swap_remove(pos);
                    }
                }
            }
            assert!(m.resident_count() <= cap);
        }
    }
}

#[test]
fn prop_occupancy_respects_limits() {
    let spec = GpuSpec::kepler_k20();
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed ^ 0xF00D);
        let k = KernelResources {
            threads_per_block: 32 * (1 + rng.below(32) as u32),
            regs_per_thread: 1 + rng.below(255) as u32,
            smem_per_block: rng.below(48 * 1024) as u32,
        };
        if k.threads_per_block > spec.max_threads_per_sm {
            continue;
        }
        let occ = occupancy(&spec, &k);
        assert!(occ.blocks_per_sm <= spec.max_blocks_per_sm);
        assert!(
            occ.blocks_per_sm * k.threads_per_block
                <= spec.max_threads_per_sm,
            "seed {seed}: thread limit violated"
        );
        assert!(occ.occupancy <= 1.0 && occ.occupancy >= 0.0);
        assert_eq!(occ.max_size, occ.blocks_per_sm * spec.sms);
    }
}

#[test]
fn prop_combiner_idle_timeout_respects_max_interval() {
    // after any sequence of arrivals, a poll at last_arrival + gap flushes
    // iff gap > 2 * max_interval
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed ^ 0x1234);
        let mut c = Combiner::new(CombinePolicy::Adaptive, 1000, false);
        let mut now = 0.0;
        let n = 2 + rng.below(50);
        for i in 0..n {
            now += rng.exponential(0.003);
            c.insert(pending(i as u64, None, 1), now);
        }
        let mi = c.max_interval();
        assert!(c.poll(now + 1.99 * mi).is_none(), "seed {seed}: early flush");
        assert!(
            c.poll(now + 2.01 * mi + 1e-9).is_some(),
            "seed {seed}: missed idle flush"
        );
    }
}
