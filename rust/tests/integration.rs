//! End-to-end integration: full applications over the complete stack
//! (chares -> coordinator -> combiner/chare-table/hybrid -> PJRT kernels).

use gcharm::apps::md::{self, MdConfig};
use gcharm::apps::nbody::{self, dataset::DatasetSpec, NbodyConfig};
use gcharm::apps::spmv::{self, SpmvConfig};
use gcharm::coordinator::{
    CombinePolicy, Config, DataPolicy, RoutePolicy, SplitPolicy,
};

fn tiny_nbody(policy: DataPolicy, combine: CombinePolicy) -> NbodyConfig {
    let mut cfg = NbodyConfig::new(DatasetSpec::tiny());
    cfg.iters = 2;
    cfg.runtime = Config {
        pes: 2,
        combine,
        data_policy: policy,
        table_slots: 256,
        ..Config::default()
    };
    cfg.pieces_per_pe = 2;
    cfg
}

#[test]
fn nbody_runs_adaptive_reuse_sorted() {
    let cfg = tiny_nbody(DataPolicy::ReuseSorted, CombinePolicy::Adaptive);
    let r = nbody::run(&cfg).unwrap();
    assert_eq!(r.energies.len(), 2);
    assert!(r.energies.iter().all(|e| e.is_finite()));
    assert!(r.report.launches > 0, "no combined launches happened");
    assert!(r.report.gpu_requests > 0);
    // chunked walks produce reuse hits
    assert!(
        r.report.table_hits > 0,
        "expected residency hits from chunked interaction lists"
    );
    assert!(r.buckets > 4);
}

#[test]
fn nbody_runs_no_reuse() {
    let cfg = tiny_nbody(DataPolicy::NoReuse, CombinePolicy::Adaptive);
    let r = nbody::run(&cfg).unwrap();
    assert!(r.energies.iter().all(|e| e.is_finite()));
    assert_eq!(r.report.table_hits, 0, "NoReuse must not touch the table");
    assert_eq!(r.report.saved_bytes, 0);
}

#[test]
fn nbody_runs_static_combining() {
    let cfg = tiny_nbody(DataPolicy::Reuse, CombinePolicy::StaticEvery(100));
    let r = nbody::run(&cfg).unwrap();
    assert!(r.energies.iter().all(|e| e.is_finite()));
    assert!(r.report.launches > 0);
}

#[test]
fn nbody_policies_agree_on_physics() {
    // The three data policies are performance strategies: the energies
    // they produce must match to f32 kernel tolerance.
    let a = nbody::run(&tiny_nbody(DataPolicy::NoReuse, CombinePolicy::Adaptive))
        .unwrap();
    let b = nbody::run(&tiny_nbody(DataPolicy::Reuse, CombinePolicy::Adaptive))
        .unwrap();
    let c = nbody::run(&tiny_nbody(
        DataPolicy::ReuseSorted,
        CombinePolicy::Adaptive,
    ))
    .unwrap();
    for i in 0..a.energies.len() {
        let scale = a.energies[i].abs().max(1e-9);
        assert!(
            (a.energies[i] - b.energies[i]).abs() / scale < 1e-3,
            "NoReuse vs Reuse energy mismatch at iter {i}: {} vs {}",
            a.energies[i],
            b.energies[i]
        );
        assert!(
            (a.energies[i] - c.energies[i]).abs() / scale < 1e-3,
            "NoReuse vs ReuseSorted energy mismatch at iter {i}"
        );
    }
}

#[test]
fn nbody_cpu_only_matches_gpu_physics() {
    let cfg = tiny_nbody(DataPolicy::NoReuse, CombinePolicy::Adaptive);
    let gpu = nbody::run(&cfg).unwrap();
    let cpu = nbody::run_cpu_only(&cfg).unwrap();
    assert_eq!(cpu.report.launches, 0, "cpu-only must not launch kernels");
    for i in 0..gpu.energies.len() {
        let scale = gpu.energies[i].abs().max(1e-9);
        assert!(
            (gpu.energies[i] - cpu.energies[i]).abs() / scale < 1e-3,
            "cpu vs gpu energy mismatch at iter {i}: {} vs {}",
            cpu.energies[i],
            gpu.energies[i]
        );
    }
}

#[test]
fn nbody_handtuned_matches_physics() {
    let cfg = tiny_nbody(DataPolicy::NoReuse, CombinePolicy::Adaptive);
    let rt = nbody::run(&cfg).unwrap();
    let ht = nbody::handtuned::run_handtuned(&cfg).unwrap();
    assert!(ht.report.launches > 0);
    for i in 0..rt.energies.len() {
        let scale = rt.energies[i].abs().max(1e-9);
        assert!(
            (rt.energies[i] - ht.energies[i]).abs() / scale < 1e-3,
            "handtuned energy mismatch at iter {i}"
        );
    }
}

#[test]
fn nbody_energy_roughly_conserved() {
    // with a small dt, total energy drifts slowly
    let mut cfg = tiny_nbody(DataPolicy::ReuseSorted, CombinePolicy::Adaptive);
    cfg.dt = 1e-4;
    cfg.iters = 4;
    let r = nbody::run(&cfg).unwrap();
    let e0 = r.energies[0];
    let e_last = *r.energies.last().unwrap();
    let drift = (e_last - e0).abs() / e0.abs().max(1e-12);
    assert!(drift < 0.2, "energy drift {drift} too large");
}

#[test]
fn nbody_sharded_pool_matches_physics() {
    // 4-device pool with affinity+steal routing: same physics as the
    // single-device run, and the per-device breakdown must account for
    // every launch.
    let single = tiny_nbody(DataPolicy::ReuseSorted, CombinePolicy::Adaptive);
    let mut sharded =
        tiny_nbody(DataPolicy::ReuseSorted, CombinePolicy::Adaptive);
    sharded.runtime.devices = 4;
    sharded.runtime.route = RoutePolicy::AffinitySteal;
    let a = nbody::run(&single).unwrap();
    let b = nbody::run(&sharded).unwrap();
    for i in 0..a.energies.len() {
        let scale = a.energies[i].abs().max(1e-9);
        assert!(
            (a.energies[i] - b.energies[i]).abs() / scale < 1e-3,
            "sharded energy mismatch at iter {i}: {} vs {}",
            a.energies[i],
            b.energies[i]
        );
    }
    assert_eq!(b.report.device_stats.len(), 4);
    let dev_launches: u64 =
        b.report.device_stats.iter().map(|d| d.launches).sum();
    assert_eq!(dev_launches, b.report.launches, "device breakdown accounts");
    let dev_requests: u64 =
        b.report.device_stats.iter().map(|d| d.requests).sum();
    assert_eq!(dev_requests, b.report.gpu_requests);
    assert!(
        b.report.device_stats.iter().filter(|d| d.launches > 0).count() > 1,
        "work must spread over more than one device"
    );
}

#[test]
fn nbody_round_robin_routing_runs() {
    let mut cfg = tiny_nbody(DataPolicy::ReuseSorted, CombinePolicy::Adaptive);
    cfg.runtime.devices = 2;
    cfg.runtime.route = RoutePolicy::RoundRobin;
    let r = nbody::run(&cfg).unwrap();
    assert!(r.energies.iter().all(|e| e.is_finite()));
    assert_eq!(r.report.steals, 0, "round-robin must never steal");
    assert!(
        r.report.device_stats.iter().all(|d| d.launches > 0),
        "round-robin spreads launches over both devices"
    );
}

#[test]
fn md_sharded_pool_matches_physics() {
    let single = tiny_md(SplitPolicy::AdaptiveItems, true);
    let mut sharded = tiny_md(SplitPolicy::AdaptiveItems, true);
    sharded.runtime.devices = 2;
    let a = md::run(&single).unwrap();
    let b = md::run(&sharded).unwrap();
    for i in 0..a.energies.len() {
        let scale = a.energies[i].abs().max(1e-9);
        assert!(
            (a.energies[i] - b.energies[i]).abs() / scale < 1e-2,
            "sharded MD energy mismatch at step {i}"
        );
    }
    assert_eq!(b.report.device_stats.len(), 2);
}

fn tiny_md(split: SplitPolicy, hybrid: bool) -> MdConfig {
    let mut cfg = MdConfig::new(600);
    cfg.grid = 4;
    cfg.box_l = 8.0;
    cfg.steps = 3;
    cfg.runtime = Config {
        pes: 2,
        split,
        hybrid,
        ..Config::default()
    };
    cfg
}

#[test]
fn md_runs_hybrid_adaptive() {
    let r = md::run(&tiny_md(SplitPolicy::AdaptiveItems, true)).unwrap();
    assert_eq!(r.energies.len(), 3);
    assert!(r.energies.iter().all(|e| e.is_finite() && *e > 0.0));
    // hybrid: both devices did work
    assert!(r.report.cpu_requests > 0, "cpu side never used");
    assert!(r.report.gpu_requests > 0, "gpu side never used");
}

#[test]
fn md_runs_static_split() {
    let r = md::run(&tiny_md(SplitPolicy::StaticCount, true)).unwrap();
    assert!(r.energies.iter().all(|e| e.is_finite()));
    assert!(r.report.cpu_requests > 0);
}

#[test]
fn md_gpu_only_mode() {
    let r = md::run(&tiny_md(SplitPolicy::AdaptiveItems, false)).unwrap();
    assert_eq!(r.report.cpu_requests, 0);
    assert!(r.report.gpu_requests > 0);
}

fn tiny_spmv() -> SpmvConfig {
    let mut cfg = SpmvConfig::new(300);
    cfg.iters = 4;
    cfg.max_row_nnz = 300;
    cfg.runtime = Config { pes: 2, ..Config::default() };
    cfg
}

#[test]
fn spmv_runs_through_the_registry_api_and_converges() {
    // The third workload registers its own kernel family through the
    // public API (no coordinator/runtime edits) and must behave like the
    // plain-loop oracle.
    let cfg = tiny_spmv();
    let r = spmv::run(&cfg).unwrap();
    let want = spmv::reference_residuals(&cfg);
    assert_eq!(r.residuals.len(), want.len());
    for (i, (got, want)) in r.residuals.iter().zip(&want).enumerate() {
        let scale = want.abs().max(1e-9);
        assert!(
            (got - want).abs() / scale < 1e-2,
            "sweep {i}: residual {got} vs reference {want}"
        );
    }
    assert!(
        r.residuals.last().unwrap() < &r.residuals[0],
        "Jacobi must converge"
    );
    // the family shows up in the per-kind report under its own name
    let k = r.report.kind("spmv_row").expect("spmv kind stats");
    assert!(k.gpu_requests + k.cpu_requests > 0);
    // hybrid eligibility: with the default config both sides did work
    assert!(r.report.cpu_requests > 0, "spmv cpu fallback never used");
    assert!(r.report.gpu_requests > 0, "spmv gpu side never used");
}

#[test]
fn spmv_sharded_pool_matches_single_device() {
    let single = tiny_spmv();
    let mut sharded = tiny_spmv();
    sharded.runtime.devices = 2;
    let a = spmv::run(&single).unwrap();
    let b = spmv::run(&sharded).unwrap();
    for (i, (x, y)) in a.residuals.iter().zip(&b.residuals).enumerate() {
        let scale = x.abs().max(1e-9);
        assert!(
            (x - y).abs() / scale < 1e-2,
            "sweep {i}: sharded spmv residual drift: {x} vs {y}"
        );
    }
    assert_eq!(b.report.device_stats.len(), 2);
}

#[test]
fn md_matches_single_core_physics() {
    let cfg = tiny_md(SplitPolicy::AdaptiveItems, true);
    let rt = md::run(&cfg).unwrap();
    let sc = md::run_single_core_cpu(&cfg);
    for i in 0..rt.energies.len() {
        let scale = sc.energies[i].abs().max(1e-9);
        assert!(
            (rt.energies[i] - sc.energies[i]).abs() / scale < 1e-2,
            "step {i}: runtime KE {} vs single-core KE {}",
            rt.energies[i],
            sc.energies[i]
        );
    }
}
