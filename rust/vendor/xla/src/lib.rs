//! Type-compatible stub of the `xla` crate (github.com/LaurentMazare/xla-rs
//! surface used by gcharm's PJRT backend).
//!
//! The offline build image bakes in the real crate; where it is absent this
//! stub keeps `--features pjrt` compiling. Every operation fails at
//! `PjRtClient::cpu()`, so the runtime falls back to the native sim backend
//! before any other stubbed method can be reached.

use std::path::Path;

/// Stub error: carries a message, formats like the real crate's error.
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub<T>() -> Result<T> {
    Err(Error(
        "xla stub: the real PJRT toolchain is not present in this build"
            .to_string(),
    ))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub()
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        stub()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        stub()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        stub()
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        stub()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        stub()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub()
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub()
    }
}
